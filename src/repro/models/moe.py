"""Mixture-of-Experts transformer (llama4-maverick, olmoe).

Expert dispatch is a *banking problem* (DESIGN.md Sec 2): experts are banks,
the router emits the access pattern, capacity is the port count, and the
token->expert crossbar is the FO/FI fan the paper's metrics size.  The
banking solver picks the expert-parallel layout (see parallel/sharding.py);
here we implement the datapath.

Two implementations:

* ``dense``  -- every expert runs on every token, outputs mixed by routing
  probability.  Exact (no capacity drops); O(T*E*F) -- the smoke/oracle path
  and the reference for the moe_dispatch Pallas kernel.
* ``sorted`` -- production path: top-k routing, argsort tokens by expert,
  capacity-bounded scatter into an (E, C, D) buffer (the all-to-all when E
  is sharded over the model axis), per-expert SwiGLU, weighted scatter-add
  back.  Tokens past capacity are dropped, exactly like Switch/GShard.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.hints import hint
from .layers import dense_init, rms_norm, split_keys, swiglu
from . import transformer as tfm

Array = jax.Array
Params = Dict[str, Any]


def init_moe_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    p = tfm.init_dense_params(cfg, key, dtype)
    L, D, E, Fm = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = split_keys(jax.random.fold_in(key, 7), 4)
    lyr = p["layers"]
    if not cfg.shared_expert:
        # routed experts replace the dense FFN entirely
        for k in ("w_gate", "w_up", "w_down"):
            del lyr[k]
    lyr["router"] = dense_init(ks[0], (L, D, E), scale=0.02, dtype=jnp.float32)
    lyr["we_gate"] = dense_init(ks[1], (L, E, D, Fm), scale=1 / math.sqrt(D), dtype=dtype)
    lyr["we_up"] = dense_init(ks[2], (L, E, D, Fm), scale=1 / math.sqrt(D), dtype=dtype)
    lyr["we_down"] = dense_init(ks[3], (L, E, Fm, D), scale=1 / math.sqrt(Fm), dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Routing + dispatch
# ---------------------------------------------------------------------------


def _route(cfg: ArchConfig, router_w: Array, xt: Array):
    """xt (T, D) -> (probs (T, K), idx (T, K), aux load-balance loss)."""
    logits = xt.astype(jnp.float32) @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss: E * sum_e f_e * p_e
    E = probs.shape[-1]
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32)
    fe = one_hot.mean(0)
    aux = E * jnp.sum(fe * me)
    return top_p, top_i, aux


def moe_ffn_dense(cfg: ArchConfig, lp, h: Array) -> Tuple[Array, Array]:
    """Oracle path: run all experts on all tokens (small shapes only)."""
    B, S, D = h.shape
    xt = h.reshape(-1, D)
    top_p, top_i, aux = _route(cfg, lp["router"], xt)
    gates = jnp.zeros((xt.shape[0], cfg.n_experts), jnp.float32)
    gates = gates.at[jnp.arange(xt.shape[0])[:, None], top_i].set(top_p)
    g = jnp.einsum("td,edf->tef", xt, lp["we_gate"])
    u = jnp.einsum("td,edf->tef", xt, lp["we_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, lp["we_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gates)
    return out.reshape(B, S, D).astype(h.dtype), aux


def capacity(cfg: ArchConfig, T: int) -> int:
    c = int(math.ceil(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)


def moe_ffn_sorted(cfg: ArchConfig, lp, h: Array) -> Tuple[Array, Array]:
    """Production path: sort-based capacity dispatch (see module doc)."""
    B, S, D = h.shape
    T = B * S
    K, E = cfg.top_k, cfg.n_experts
    C = capacity(cfg, T)
    xt = h.reshape(T, D)
    top_p, top_i, aux = _route(cfg, lp["router"], xt)

    flat_e = top_i.reshape(-1)                      # (T*K,)
    order = jnp.argsort(flat_e)                     # stable
    sorted_e = flat_e[order]
    tok = order // K                                # source token per slot
    # rank within expert group = index - first index of this expert value
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * K) - first
    keep = rank < C
    slot = jnp.where(keep, rank, C)                 # overflow -> dropped row

    buf = jnp.zeros((E, C + 1, D), h.dtype)
    buf = buf.at[sorted_e, slot].set(xt[tok], mode="drop")
    buf = hint(buf[:, :C], "expert_buffer")         # (E, C, D)

    g = jnp.einsum("ecd,edf->ecf", buf, lp["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, lp["we_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, lp["we_down"])

    w = top_p.reshape(-1)[order]
    y_tok = y[sorted_e, jnp.minimum(slot, C - 1)]   # (T*K, D)
    y_tok = jnp.where(keep[:, None], y_tok, 0)
    out = jnp.zeros((T, D), jnp.float32)
    out = out.at[tok].add(y_tok.astype(jnp.float32) * w[:, None])
    return out.reshape(B, S, D).astype(h.dtype), aux


def moe_ffn_a2a(cfg: ArchConfig, lp, h: Array) -> Tuple[Array, Array]:
    """Expert-parallel dispatch via shard_map (Perf iteration, see
    EXPERIMENTS.md §Perf olmoe/llama4).

    Banking view: experts are banks on the 'model' mesh axis; the dispatch
    crossbar is *local selection* (tokens are already replicated across the
    model axis by the block-input all-gather the attention path pays
    anyway), and the combine crossbar is one ``psum_scatter`` that lands
    the output directly in the sequence-sharded residual layout.  Per-layer
    collective bytes drop from O(E*C*D) buffer all-reduces to one
    (T_local x D) reduce-scatter.

    Requires a live mesh in the hint policy; falls back to the sorted
    implementation otherwise (single-device smoke tests).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..parallel.hints import policy_value

    mesh = policy_value("__mesh__")
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return moe_ffn_sorted(cfg, lp, h)
    n_model = mesh.shape["model"]
    E, K = cfg.n_experts, cfg.top_k
    if E % n_model or h.shape[1] % n_model:
        return moe_ffn_sorted(cfg, lp, h)
    E_loc = E // n_model
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp_weights = bool(policy_value("__fsdp__", False)) and "data" in dp
    Bg, S, D = h.shape

    def local_fn(h_loc, router_w, we_gate, we_up, we_down):
        # h_loc: (B_loc, S, D) -- replicated over 'model' within a data row
        # FSDP mode: expert weights arrive still cut on their F dim; gather
        # HERE (inside the remat'd layer body) so the gathered copies are
        # transient per layer instead of living across the whole scan.
        if fsdp_weights:
            we_gate = jax.lax.all_gather(we_gate, "data", axis=2, tiled=True)
            we_up = jax.lax.all_gather(we_up, "data", axis=2, tiled=True)
            we_down = jax.lax.all_gather(we_down, "data", axis=1, tiled=True)
        m = jax.lax.axis_index("model")
        B_loc = h_loc.shape[0]
        T = B_loc * S
        xt = h_loc.reshape(T, D)
        top_p, top_i, aux = _route(cfg, router_w, xt)
        C = capacity(cfg, T)

        flat_e = top_i.reshape(-1)
        mine = (flat_e // E_loc) == m
        local_e = jnp.clip(flat_e - m * E_loc, 0, E_loc - 1)
        key = jnp.where(mine, local_e, E_loc)     # foreign slots sort last
        order = jnp.argsort(key)
        skey = key[order]
        tok = order // K
        first = jnp.searchsorted(skey, skey, side="left")
        rank = jnp.arange(T * K) - first
        keep = (skey < E_loc) & (rank < C)
        slot = jnp.where(keep, rank, C)
        e_idx = jnp.minimum(skey, E_loc - 1)

        buf = jnp.zeros((E_loc, C + 1, D), h_loc.dtype)
        buf = buf.at[jnp.where(keep, e_idx, E_loc - 1), slot].set(
            xt[tok], mode="drop")
        buf = buf[:, :C]

        g = jnp.einsum("ecd,edf->ecf", buf, we_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, we_up)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, we_down)

        w = top_p.reshape(-1)[order]
        y_tok = y[e_idx, jnp.minimum(slot, C - 1)]
        y_tok = jnp.where(keep[:, None], y_tok.astype(jnp.float32), 0.0)
        out = jnp.zeros((T, D), jnp.float32)
        out = out.at[tok].add(y_tok * w[:, None])
        out = out.reshape(B_loc, S, D)
        # combine crossbar: sum each token's expert contributions across the
        # model axis AND land sequence-sharded (the residual layout)
        out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                   tiled=True)
        aux = jax.lax.pmean(aux, "model")
        return out.astype(h_loc.dtype), aux

    w_up_spec = P("model", None, "data" if fsdp_weights else None)
    w_dn_spec = P("model", "data" if fsdp_weights else None, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  w_up_spec, w_up_spec, w_dn_spec),
        out_specs=(P(dp, "model", None), P()),
        check_rep=False,
    )
    out, aux = fn(h, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
    return out, aux


MOE_IMPLS = {"dense": moe_ffn_dense, "sorted": moe_ffn_sorted,
             "a2a": moe_ffn_a2a}


# ---------------------------------------------------------------------------
# Forward passes (mirror transformer.py, threading aux loss through scan)
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params: Params, tokens: Array,
            impl: str = "sorted", block_k: int = 1024
            ) -> Tuple[Array, Array]:
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(tfm.layer_windows(cfg))
    lp = params["layers"]
    moe_fn = MOE_IMPLS[impl]

    def body(carry, xs):
        x, aux = carry
        lp_l, window = xs
        h = hint(rms_norm(x, lp_l["ln1"], cfg.norm_eps), "block_in")
        k, v = tfm._project_kv(cfg, lp_l, h, 0)
        attn = tfm._attn(cfg, lp_l, h, k_full=k, v_full=v, window=window,
                         q_offset=0, kv_len=None, block_k=block_k)
        x = x + attn
        h = hint(rms_norm(x, lp_l["ln2"], cfg.norm_eps), "block_in")
        delta, aux_l = moe_fn(cfg, lp_l, h)
        if cfg.shared_expert:
            delta = delta + swiglu(h, lp_l["w_gate"], lp_l["w_up"], lp_l["w_down"])
        return (hint(x + delta, "residual"), aux + aux_l), None

    body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (lp, windows))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return h, aux / cfg.n_layers


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, Array],
            impl: str = "sorted", aux_weight: float = 0.01) -> Array:
    h, aux = forward(cfg, params, batch["tokens"], impl=impl)
    return tfm.chunked_xent(cfg, params, h, batch["labels"]) + aux_weight * aux


def decode_step(cfg: ArchConfig, params: Params, cache: tfm.KVCache,
                tokens: Array, impl: str = "sorted", block_k: int = 1024
                ) -> Tuple[Array, tfm.KVCache]:
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(tfm.layer_windows(cfg))
    lp = params["layers"]
    pos = cache.pos
    moe_fn = MOE_IMPLS[impl]

    def body(x, xs):
        lp_l, window, kc, vc = xs

        def ffn(lp_, hnorm):
            delta, _ = moe_fn(cfg, lp_, hnorm)
            if cfg.shared_expert:
                delta = delta + swiglu(hnorm, lp_["w_gate"], lp_["w_up"],
                                       lp_["w_down"])
            return delta

        x, (kc, vc) = tfm.dense_layer(cfg, lp_l, x, window, cache_kv=(kc, vc),
                                      pos=pos, block_k=block_k, ffn=ffn)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (lp, windows, cache.k, cache.v))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h)[:, 0]
    return logits, tfm.KVCache(k_new, v_new, pos + 1)


def prefill(cfg: ArchConfig, params: Params, tokens: Array, max_len: int,
            impl: str = "sorted", block_k: int = 1024):
    B, S = tokens.shape
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(tfm.layer_windows(cfg))
    lp = params["layers"]
    moe_fn = MOE_IMPLS[impl]

    def body(x, xs):
        lp_l, window = xs

        def ffn(lp_, hnorm):
            delta, _ = moe_fn(cfg, lp_, hnorm)
            if cfg.shared_expert:
                delta = delta + swiglu(hnorm, lp_["w_gate"], lp_["w_up"],
                                       lp_["w_down"])
            return delta

        x, (k, v) = tfm.dense_layer(cfg, lp_l, x, window, block_k=block_k,
                                    ffn=ffn)
        pad = max_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (k, v)

    body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, (lp, windows))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h[:, -1:])[:, 0]
    return logits, tfm.KVCache(ks, vs, jnp.asarray(S, jnp.int32))
