"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching server against synthetic requests and reports
throughput; ``--smoke`` uses the reduced config (CPU-sized).

The KV-pool banking problem goes through the async PlanService front
door: the server starts on the ticket's fallback artifact (no solver
wait) and hot-swaps to the solved layout between decode ticks.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --requests 8 --max-batch 4

With ``--fabric`` the cold solve runs on REMOTE shard workers: the
launcher opens a :class:`~repro.core.fabric.SolveFabric` listener
(``--fabric-listen host:port``) and prints the address; attach any
number of hosts with

    PYTHONPATH=src python -m repro.launch.solve_worker HOST:PORT

and the server's best-so-far promotions / solved hot-swap work exactly
as in-process -- the shards just ran somewhere else.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-store", default=None,
                    help="directory shared across serving processes; a warm "
                         "store answers the submit before the first tick")
    ap.add_argument("--plan-store-max-mb", type=float, default=None,
                    help="size-cap the plan store: LRU entries are evicted "
                         "past this many MB, and stale SIGNATURE_VERSION "
                         "entries are swept at startup")
    ap.add_argument("--fabric", action="store_true",
                    help="run cold solves on remote shard workers: opens a "
                         "SolveFabric listener and prints the address to "
                         "attach solve_worker processes to")
    ap.add_argument("--fabric-listen", default="127.0.0.1:0",
                    help="host:port the fabric accepts workers on "
                         "(port 0 = ephemeral; bind a private interface)")
    ap.add_argument("--fabric-wait-workers", type=int, default=0,
                    help="block up to 30s for this many workers before "
                         "serving (0 = serve immediately; a fabric with no "
                         "workers falls back to the in-process pool)")
    ap.add_argument("--telemetry", action="store_true",
                    help="measured-cost feedback: time every banked "
                         "gather/scatter and decode tick, rank the KV plan "
                         "with scorer=\"measured\", persist observations "
                         "in the plan store's telemetry/ sidecar, and "
                         "demote + re-solve plans the measurements prove "
                         "slow")
    ap.add_argument("--verify", choices=("off", "store", "all"),
                    default="off",
                    help="static verification: lint the KV-pool program "
                         "before solving and certify solver output before "
                         "it is cached (certificates persist beside stored "
                         "plans, which re-verify on hydrate); \"all\" also "
                         "certifies every result batch remote fabric "
                         "workers stream back, rejecting forged ones")
    ap.add_argument("--tenant", default=None,
                    help="tenant name this server submits under on a "
                         "shared multi-tenant service (per-tenant stats "
                         "slice, quotas, QoS band; see --qos and "
                         "launch/serve_fleet.py for the fleet story)")
    ap.add_argument("--qos", default=None,
                    choices=("interactive", "batch", "best_effort",
                             "default"),
                    help="QoS class to register --tenant under "
                         "(default: the registry's permissive default)")
    ap.add_argument("--joint", action="store_true",
                    help="whole-model joint planning: ONE submit_joint "
                         "covers every banked memory this architecture "
                         "serves through (kv_pool + moe_dispatch / "
                         "ssm_state), co-selected under a shared "
                         "resource budget; the server promotes ALL "
                         "pools to the joint layouts atomically "
                         "between decode ticks")
    ap.add_argument("--budget-bram", type=int, default=None,
                    help="joint budget: cap the summed BRAM draw "
                         "across the model's memories")
    ap.add_argument("--budget-luts", type=float, default=None,
                    help="joint budget: cap the summed LUT draw")
    ap.add_argument("--budget-banks", type=int, default=None,
                    help="joint budget: cap total physical banks "
                         "(duplicates included)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="print the service's stats counters (observations/"
                         "refreshes/demotions included, per-tenant slices "
                         "nested under \"tenants\") every N seconds "
                         "while serving (0 = off)")
    ap.add_argument("--trace-dir", default=None,
                    help="enable plan-plane tracing and write Chrome "
                         "trace_event JSON here: the completed-ticket "
                         "flight recorder dumps on exit, anomalies "
                         "(latency SLO, cert rejection, demotion) dump "
                         "as they happen -- load the files in "
                         "chrome://tracing or Perfetto")
    ap.add_argument("--trace-slo-ms", type=float, default=None,
                    help="flight-recorder latency SLO: a ticket slower "
                         "than this many ms end-to-end dumps its trace "
                         "as an anomaly (requires --trace-dir or "
                         "--metrics-port)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus text), /traces "
                         "(Chrome trace JSON) and /stats (registry "
                         "snapshot) on 127.0.0.1:PORT from a stdlib "
                         "HTTP thread (0 = ephemeral, address printed)")
    args = ap.parse_args()

    import numpy as np

    from ..configs import get_arch
    from ..core.fabric import SolveFabric
    from ..core.service import PlanService
    from ..core.store import DirectoryStore
    from ..models import get_model
    from ..runtime.server import Request, Server, joint_ticket, page_ticket

    # plan store + fabric first: sweeping stale-version entries, binding
    # the worker listener, and building the service all overlap the
    # model build below
    store = None
    if args.plan_store:
        max_bytes = (int(args.plan_store_max_mb * 2 ** 20)
                     if args.plan_store_max_mb is not None else None)
        store = DirectoryStore(args.plan_store, max_bytes=max_bytes)
        swept = store.sweep()
        if swept:
            print(f"plan store: swept {swept} stale-version entries")
    fabric = None
    if args.fabric:
        host, _, port = args.fabric_listen.rpartition(":")
        fabric = SolveFabric(listen=(host or "127.0.0.1", int(port)))
        print(f"solve fabric listening on {fabric.address} -- attach "
              f"workers with: python -m repro.launch.solve_worker "
              f"{fabric.address}")
        if args.fabric_wait_workers:
            if fabric.wait_for_workers(args.fabric_wait_workers,
                                       timeout=30.0):
                print(f"fabric: {fabric.workers_alive} workers attached")
            else:
                print("fabric: workers did not attach in time; cold "
                      "solves fall back to the in-process pool")
    tenants = None
    if args.tenant:
        from ..runtime.tenancy import TenantRegistry
        tenants = TenantRegistry()
        tenants.register(args.tenant, args.qos or "default")
        print(f"tenant {args.tenant!r} registered "
              f"(qos={args.qos or 'default'})")
    observe = (args.trace_dir is not None or args.metrics_port is not None
               or args.trace_slo_ms is not None)
    service = None
    if store is not None or fabric is not None or args.telemetry \
            or args.verify != "off" or tenants is not None or observe:
        service = PlanService(
            store=store,
            executor="fabric" if fabric is not None else "pool",
            fabric=fabric,
            verify=args.verify,
            tenants=tenants)
    obs_server = None
    if observe:
        service.enable_tracing(slo_ms=args.trace_slo_ms,
                               trace_dir=args.trace_dir)
        if args.trace_dir is not None:
            print(f"tracing: flight recorder armed, Chrome trace dumps "
                  f"land in {args.trace_dir}"
                  + (f" (SLO {args.trace_slo_ms:g} ms)"
                     if args.trace_slo_ms is not None else ""))
        if args.metrics_port is not None:
            from ..core.tracing import start_observability_server
            obs_server = start_observability_server(
                service.metrics, service.recorder, tracer=service.tracer,
                port=args.metrics_port)
            host_, port_ = obs_server.server_address[:2]
            print(f"metrics: http://{host_}:{port_}/metrics "
                  f"(also /traces, /stats)")
    if args.verify != "off":
        print(f"verification armed ({args.verify}): lint gate + "
              f"independent conflict certification"
              + (" + fabric batch checking" if args.verify == "all" else ""))
    if args.telemetry:
        service.enable_telemetry()
        print("telemetry: measured-cost feedback enabled "
              "(scorer=measured, demotion armed)")
    if args.stats_interval > 0 and service is not None:
        import json as json_mod
        import threading

        def _stats_loop():
            # per-tenant slices nest under "tenants" and the fabric's
            # live counters (heartbeats included) under "fabric" on
            # EVERY periodic line, not just the exit report; with
            # tracing on, the MetricsRegistry gauges ride along too
            while True:
                time.sleep(args.stats_interval)
                line = service.stats.as_dict()
                if fabric is not None:
                    fs = fabric.stats
                    line["fabric"] = {
                        "workers_alive": fabric.workers_alive,
                        "heartbeats": fs.heartbeats,
                        "leases": fs.leases,
                        "requeues": fs.requeues,
                        "evaluated": fs.evaluated,
                    }
                if service.metrics is not None:
                    snap = service.metrics.snapshot()
                    if snap.get("gauges"):
                        line["gauges"] = snap["gauges"]
                print("stats:", json_mod.dumps(line))

        threading.Thread(target=_stats_loop, daemon=True,
                         name="serve-stats").start()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)

    # submit -> ticket: model build and solver overlap; the server's first
    # tick runs from the fallback artifact if the solve hasn't landed
    t_submit = time.perf_counter()
    if args.joint:
        from ..core.jointplan import ResourceBudget
        budget = None
        if (args.budget_bram is not None or args.budget_luts is not None
                or args.budget_banks is not None):
            budget = ResourceBudget(bram=args.budget_bram,
                                    lut=args.budget_luts,
                                    banks=args.budget_banks)
        ticket = joint_ticket(cfg, max_len=args.max_len,
                              page=min(16, args.max_len // 4),
                              readers=args.max_batch, service=service,
                              budget=budget,
                              scorer="measured" if args.telemetry else None,
                              tenant=args.tenant)
        print(f"submitted joint plan ({len(ticket.members) or 'cached'} "
              f"memories) in "
              f"{(time.perf_counter() - t_submit) * 1e3:.2f} ms "
              f"(ticket: {ticket.status})")
    else:
        ticket = page_ticket(cfg, max_len=args.max_len,
                             page=min(16, args.max_len // 4),
                             readers=args.max_batch, service=service,
                             scorer="measured" if args.telemetry else None,
                             tenant=args.tenant)
        print(f"submitted KV-pool plan in "
              f"{(time.perf_counter() - t_submit) * 1e3:.2f} ms "
              f"(ticket: {ticket.status})")
    server = Server(model, max_batch=args.max_batch, max_len=args.max_len,
                    kv_plan=ticket)
    print("serving from:", server.pager.artifact.describe())
    print(f"page pool: {server.pager.slots} slots x "
          f"{server.pager.pages_per_slot} pages x "
          f"{server.pager.page_size} tokens")

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        prompt = rng.integers(2, cfg.vocab - 1,
                              size=int(rng.integers(3, 8))).astype(np.int32)
        server.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))
    t0 = time.perf_counter()
    server.run(max_ticks=5000)
    dt = time.perf_counter() - t0
    total_tokens = args.requests * args.max_new
    if server.promotions:
        print(f"promoted to best-so-far layouts {server.promotions}x "
              f"before the search drained")
    if server.swaps:
        print(f"hot-swapped to solved layout after tick <= {server.ticks}: "
              f"{server.pager.artifact.describe()}")
    if args.joint:
        if server.joint_promotions or server.joint_swaps:
            print(f"joint: {server.joint_promotions} coherent all-pool "
                  f"promotions, {server.joint_swaps} final swaps "
                  f"(generations {server.generations}, "
                  f"coherent={server.coherent})")
        if ticket.done():
            jp = ticket.result()
            print(f"joint selection: fits={jp.fits()} "
                  f"feasible={jp.feasible} "
                  f"total={jp.total_use.as_dict()}")
    print(f"served {args.requests} requests ({total_tokens} tokens) in "
          f"{server.ticks} ticks, {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on this host)")
    if service is not None and service.stats.fabric_solves:
        print(f"fabric: {service.stats.fabric_solves} remote solves, "
              f"{service.stats.fabric_leases} leases, "
              f"{service.stats.fabric_cut_broadcasts} cut broadcasts, "
              f"{service.stats.fabric_requeues} requeues")
    if args.verify != "off" and service is not None:
        s = service.stats
        print(f"verification: {s.certified} certified, "
              f"{s.cert_failures} refused, {s.cert_rejected} fabric "
              f"batches rejected, {s.lint_errors} lint refusals")
    if args.tenant and service is not None:
        import json as json_mod
        slice_ = service.stats.for_tenant(args.tenant)
        print(f"tenant {args.tenant!r} stats:",
              json_mod.dumps({k: v for k, v
                              in slice_.as_dict(False).items() if v}))
    if args.telemetry and service is not None \
            and service.telemetry is not None:
        flushed = service.telemetry.flush()
        s = service.stats
        print(f"telemetry: {s.observations} observations "
              f"({flushed} flushed at exit), {s.refreshes} scorer "
              f"refreshes, {s.demotions} demotions")
    if service is not None and service.recorder is not None:
        rec = service.recorder
        n_anom = len(rec.anomalies())
        if args.trace_dir is not None and rec.traces():
            import os as os_mod
            path = rec.dump(os_mod.path.join(args.trace_dir,
                                             "serve_trace.json"))
            print(f"tracing: {len(rec.traces())} ticket traces "
                  f"({n_anom} anomalies) -> {path}")
        elif n_anom:
            print(f"tracing: {n_anom} anomalies recorded "
                  f"(pass --trace-dir to keep the dumps)")
    if obs_server is not None:
        obs_server.shutdown()
    if fabric is not None:
        fabric.shutdown()


if __name__ == "__main__":
    main()
