import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) so
the XLA_FLAGS line above executes before jax initializes -- the two lines
at the top of this file are load-bearing and must stay first.

For each cell we build ShapeDtypeStruct stand-ins (no allocation), attach
NamedShardings from the banking-solver bridge, ``jit(...).lower().compile()``
against the production mesh, and record ``memory_analysis()`` /
``cost_analysis()`` plus the collective-op byte census parsed from the
compiled HLO (for EXPERIMENTS.md Roofline).

    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_arch, _ALIASES
from ..configs.base import ArchConfig, ShapeConfig
from ..models import get_model
from ..optim import adamw
from ..parallel import sharding as shd
from ..parallel.hints import sharding_policy
from . import steps
from .mesh import make_production_mesh


def make_policy(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Dict[str, P]:
    """Activation-sharding policy per cell (see parallel/hints.py).

    Attention families run Megatron-SP: residual stream sequence-sharded
    over 'model', block inputs gathered.  SSM/hybrid shard the residual on
    channels instead (the SSD chunk scan cannot have a sharded leading
    axis).  Decode shapes leave activations to propagation (seq==1).
    """
    dp = shd.dp_axes(mesh)
    pol: Dict[str, P] = {"expert_buffer": P("model", None, None)}
    if shape.kind in ("train", "prefill"):
        if cfg.family in ("ssm", "hybrid"):
            pol["residual"] = P(dp, None, "model")
        else:
            pol["residual"] = P(dp, "model", None)
            pol["block_in"] = P(dp, None, None)
        pol["logits"] = P(dp, None, "model")
    return pol

SKIPS: Dict[tuple, str] = {}
for _a in ["deepseek_67b", "qwen2_7b", "internlm2_20b", "chameleon_34b",
           "llama4_maverick", "olmoe_1b_7b", "whisper_base"]:
    SKIPS[(_a, "long_500k")] = (
        "pure full attention (or unmodelled chunked variant): long_500k "
        "needs sub-quadratic attention -- skip per assignment, DESIGN.md "
        "Arch-applicability")


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shape_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), shape_tree, spec_tree)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the data batch of one step."""
    b, s = shape.global_batch, shape.seq_len
    bs = shd.batch_specs(cfg, shape, mesh)
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = _sds((b, s), jnp.int32, mesh, bs["tokens"])
        if shape.kind == "train":
            out["labels"] = _sds((b, s), jnp.int32, mesh, bs["labels"])
        if cfg.family == "encdec":
            out["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16, mesh,
                                 bs["frames"])
    else:  # decode / long_decode: one new token against a seq_len cache
        out["tokens"] = _sds((b, 1), jnp.int32, mesh,
                             P(bs["tokens"][0], None))
    return out


def cache_structs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    model = get_model(cfg)
    if cfg.family == "encdec":
        from ..models.encdec import EncDecCache
        L, B, Hkv, Dh = cfg.n_layers, shape.global_batch, cfg.n_kv_heads, cfg.hd
        kvshape = (L, B, shape.seq_len, Hkv, Dh)
        shapes = EncDecCache(
            k_self=jax.ShapeDtypeStruct(kvshape, jnp.bfloat16),
            v_self=jax.ShapeDtypeStruct(kvshape, jnp.bfloat16),
            k_cross=jax.ShapeDtypeStruct(kvshape, jnp.bfloat16),
            v_cross=jax.ShapeDtypeStruct(kvshape, jnp.bfloat16),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
        )
    else:
        shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
    specs = shd.cache_specs(cfg, shape, mesh)
    return _tree_sds(shapes, specs, mesh)


def params_structs(cfg: ArchConfig, mesh: Mesh, fsdp: bool):
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = shd.param_specs(shapes, mesh, fsdp=fsdp)
    return _tree_sds(shapes, specs, mesh), specs


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n=]*=\s*([a-z0-9](?:[^\s(]*))\(", re.I)

SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|u64)"
                      r"\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes of every collective op in compiled HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(
            r".*=\s*((?:f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64|tuple|\()"
            r".*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", stripped)
        if not m:
            continue
        kind = m.group(2).lower()
        nbytes = 0.0
        for dt, dims in SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    return out


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               fsdp: Optional[bool] = None, block_k: int = 1024,
               variant: str = "baseline",
               bf16_opt: bool = False) -> Dict[str, Any]:
    """variant: baseline | moe_a2a (shard_map expert dispatch) |
    ring_cache (windowed local-layer KV rings -- local:global archs)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg, moe_impl="a2a" if variant == "moe_a2a" else "sorted")
    if fsdp is None:
        # params: model-axis sharding only unless the bf16 copy would not
        # fit comfortably per device -- then cut the data axis too (FSDP /
        # ZeRO-3).  Optimizer state is always data+model cut (ZeRO-1).
        shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        pbytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                     for s in jax.tree.leaves(shapes))
        per_dev = pbytes / mesh.shape["model"]
        fsdp = per_dev > 2 * 2**30

    policy = make_policy(cfg, shape, mesh)
    if variant == "moe_a2a":
        policy["__mesh__"] = mesh
        policy["__fsdp__"] = fsdp

    t0 = time.time()
    with jax.default_device(jax.devices()[0]), \
            sharding_policy(policy):
        if variant == "ring_cache":
            assert shape.kind in ("decode", "long_decode")
            from ..models import transformer as tfm
            p_structs, _ = params_structs(cfg, mesh, fsdp=fsdp)
            G, R = tfm.grouped_layout(cfg)
            W, Hkv, Dh = cfg.sliding_window, cfg.n_kv_heads, cfg.hd
            B = shape.global_batch
            dp = shd.dp_axes(mesh)
            nb = None if B == 1 else dp
            seq_all = tuple(a for a in (*dp, "model")) if B == 1 else "model"
            kv_loc = P(None, None, nb, "model" if B == 1 else None, None, None)
            kv_glob = P(None, nb, seq_all, None, None)
            cache_shapes = jax.eval_shape(
                lambda: tfm.init_grouped_cache(cfg, B, shape.seq_len))
            cache = _tree_sds(
                cache_shapes,
                tfm.GroupedKVCache(k_local=kv_loc, v_local=kv_loc,
                                   k_global=kv_glob, v_global=kv_glob,
                                   pos=P()),
                mesh)
            batch = input_specs(cfg, shape, mesh)

            def serve_ring(params, cache, tokens):
                logits, new_cache = tfm.grouped_decode_step(
                    cfg, params, cache, tokens)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                return nxt, logits, new_cache

            with mesh:
                lowered = jax.jit(serve_ring).lower(p_structs, cache,
                                                    batch["tokens"])
        elif shape.kind == "train":
            p_structs, p_specs = params_structs(cfg, mesh, fsdp=fsdp)
            moment_dt = jnp.bfloat16 if bf16_opt else jnp.float32
            opt_shapes = jax.eval_shape(
                lambda p: adamw.init(p, moment_dt),
                jax.tree.map(lambda s: s, p_structs))
            zaxes = ("data", "pod")  # ZeRO across every pure-DP axis
            opt_specs = adamw.AdamWState(
                step=P(),
                m=shd.param_specs(opt_shapes.m, mesh, fsdp=True,
                                  fsdp_axes=zaxes),
                v=shd.param_specs(opt_shapes.v, mesh, fsdp=True,
                                  fsdp_axes=zaxes),
                master=shd.param_specs(opt_shapes.master, mesh, fsdp=True,
                                       fsdp_axes=zaxes))
            opt_structs = _tree_sds(opt_shapes, opt_specs, mesh)
            batch = input_specs(cfg, shape, mesh)
            step_fn = steps.make_train_step(model, adamw.AdamWConfig())
            with mesh:
                lowered = jax.jit(step_fn).lower(p_structs, opt_structs, batch)
        elif shape.kind == "prefill":
            p_structs, _ = params_structs(cfg, mesh, fsdp=fsdp)
            batch = input_specs(cfg, shape, mesh)
            fn = steps.make_prefill_step(model, shape.seq_len)
            with mesh:
                lowered = jax.jit(fn).lower(p_structs, batch)
        elif variant == "int8_kv":
            assert shape.kind in ("decode", "long_decode")
            from ..models import transformer as tfm
            p_structs, _ = params_structs(cfg, mesh, fsdp=fsdp)
            base_specs = shd.cache_specs(cfg, shape, mesh)
            kv, scale = base_specs.k, P(*base_specs.k[:-1])
            cache_shapes = jax.eval_shape(
                lambda: tfm.init_quant_cache(cfg, shape.global_batch,
                                             shape.seq_len))
            cache = _tree_sds(
                cache_shapes,
                tfm.QuantKVCache(k_q=kv, v_q=kv, k_s=scale, v_s=scale,
                                 pos=P()),
                mesh)
            batch = input_specs(cfg, shape, mesh)

            def serve_q(params, cache, tokens):
                logits, new_cache = tfm.decode_step_quant(cfg, params, cache,
                                                          tokens)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                return nxt, logits, new_cache

            with mesh:
                lowered = jax.jit(serve_q).lower(p_structs, cache,
                                                 batch["tokens"])
        else:  # decode / long_decode
            p_structs, _ = params_structs(cfg, mesh, fsdp=fsdp)
            cache = cache_structs(cfg, shape, mesh)
            batch = input_specs(cfg, shape, mesh)
            fn = steps.make_serve_step(model)
            with mesh:
                lowered = jax.jit(fn).lower(p_structs, cache, batch["tokens"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # older JAX returns a one-element list of per-computation dicts
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "bytes_per_device_argument": getattr(
                mem, "argument_size_in_bytes", 0),
            "bytes_per_device_output": getattr(
                mem, "output_size_in_bytes", 0),
            "bytes_per_device_temp": getattr(mem, "temp_size_in_bytes", 0),
            "bytes_per_device_peak": getattr(
                mem, "peak_memory_in_bytes",
                getattr(mem, "temp_size_in_bytes", 0)),
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "moe_a2a", "ring_cache", "int8_kv"])
    ap.add_argument("--bf16-opt", action="store_true",
                    help="bf16 Adam moments (halves optimizer HBM)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        arch = _ALIASES.get(args.arch,
                            args.arch.replace("-", "_").replace(".", "_"))
        cells.append((arch, args.shape))

    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    results = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch, shape_name in cells:
            key = (arch, shape_name)
            tag = f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
            if key in SKIPS:
                print(f"SKIP  {tag}: {SKIPS[key]}")
                results.append({"arch": arch, "shape": shape_name,
                                "skipped": SKIPS[key]})
                continue
            try:
                r = lower_cell(arch, shape_name, mesh, variant=args.variant,
                               bf16_opt=args.bf16_opt)
                r["multi_pod"] = multi_pod
                results.append(r)
                print(f"OK    {tag}: compile={r['compile_s']}s "
                      f"flops={r['flops']:.3e} "
                      f"peak={r['memory']['bytes_per_device_peak']/2**30:.2f}GiB "
                      f"coll={ {k: round(v/2**20,1) for k,v in r['collective_bytes'].items()} }MiB")
            except Exception as e:
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape_name,
                                "multi_pod": multi_pod, "error": str(e)[:500]})
                print(f"FAIL  {tag}: {e}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
