"""Remote solve worker: attach this host's CPU to a SolveFabric.

    python -m repro.launch.solve_worker HOST:PORT [--procs N]

Connects to the fabric a serving launcher opened with ``--fabric``
(``launch/serve.py`` prints the address), receives candidate spaces and
work-unit leases over the wire protocol, evaluates them through the
exact same :func:`repro.core.candidates.evaluate` pipeline the
in-process pool uses, and streams scored solution batches back.  Run it
on N hosts to attach N hosts to one service.

Cut updates broadcast by the service land in a :class:`CutGate`, so a
lease already being evaluated prunes beyond-cut candidates mid-stream
-- the remote analogue of the in-process reducer gate.

The worker deliberately never imports jax: it starts in a fraction of a
second and evaluation is pure numpy, so spinning one per core is cheap.
"""

from __future__ import annotations

import argparse
import os
import queue
import socket
import threading
import time
from typing import Dict

from ..core.candidates import (
    CandidateSpace,
    CutGate,
    evaluate,
    events_to_wire,
    shard_from_indices,
    space_from_wire,
)
from ..core.fabric import read_frame, write_frame
from ..core.tracing import spans_to_wire

RESULT_BATCH = 8      # events per result frame: keeps cuts/best-so-far fresh
HB_INTERVAL = 2.0     # seconds between heartbeat frames (0 disables)


def run_worker(address: str, *, result_batch: int = RESULT_BATCH,
               hb_interval: float = HB_INTERVAL) -> None:
    """Serve leases from the fabric at ``address`` until it goes away.

    A daemon thread sends a tiny ``{"t": "hb"}`` frame every
    ``hb_interval`` seconds so the fabric can detect this process dying
    (or partitioning) within ``hb_timeout`` instead of waiting out a
    full lease timeout.  Heartbeats prove the *process* alive, not lease
    progress -- a hung evaluation still loses its lease on time.
    """
    host, _, port = address.rpartition(":")
    sock = socket.create_connection((host or "127.0.0.1", int(port)))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    send_lock = threading.Lock()
    write_frame(sock, {"t": "join", "pid": os.getpid(),
                       "host": socket.gethostname()}, send_lock)

    spaces: Dict[int, CandidateSpace] = {}
    gates: Dict[int, CutGate] = {}
    leases: "queue.Queue" = queue.Queue()
    stop = threading.Event()

    def heartbeat() -> None:
        while not stop.wait(hb_interval):
            try:
                write_frame(sock, {"t": "hb"}, send_lock)
            except OSError:
                return                    # fabric went away: main loop ends

    if hb_interval > 0:
        threading.Thread(target=heartbeat, daemon=True,
                         name="fabric-hb").start()

    def reader() -> None:
        # cuts and retirements apply IMMEDIATELY (mid-evaluation); only
        # leases queue behind the current one
        try:
            while True:
                msg = read_frame(sock)
                t = msg.get("t")
                if t == "space":
                    sid = msg["solve_id"]
                    spaces[sid] = space_from_wire(msg["payload"])
                    gates[sid] = CutGate()
                elif t == "lease":
                    leases.put(msg)
                elif t == "cuts":
                    gate = gates.get(msg["solve_id"])
                    if gate is not None:
                        gate.update(msg["cuts"])
                elif t == "retire":
                    spaces.pop(msg["solve_id"], None)
                    gate = gates.pop(msg["solve_id"], None)
                    if gate is not None:
                        gate.cancel()     # stop any straggling lease
                elif t == "shutdown":
                    break
        except Exception:
            # EOF, dead socket, or an undecodable frame: all mean this
            # fabric is no longer usable from here
            pass
        finally:
            # ALWAYS unblock the main loop -- a reader death must end
            # the process, never hang it on leases.get()
            leases.put(None)

    threading.Thread(target=reader, daemon=True, name="fabric-reader").start()

    while True:
        msg = leases.get()
        if msg is None:
            break
        sid, lid = msg["solve_id"], msg["lease_id"]
        space, gate = spaces.get(sid), gates.get(sid)
        try:
            if space is None or gate is None:
                # no space for this lease (solve retired while queued,
                # or frames raced): NACK so the fabric REQUEUES the unit
                # rather than counting it complete
                write_frame(sock, {"t": "error", "lease_id": lid,
                                   "error": f"no space for solve {sid}"},
                            send_lock)
                continue
            gate.update(msg.get("cuts") or {})
            # a traced lease carries the driver's trace_id: measure the
            # eval and result-wire stages locally (perf_counter, relative
            # to lease receipt) and echo them on the done frame so the
            # driver stitches them into ONE trace
            traced = msg.get("trace") is not None
            t_lease = time.perf_counter()
            wire_s = 0.0
            shard = shard_from_indices(space, msg["indices"])
            batch, evaluated = [], 0
            t_eval = time.perf_counter()
            for ev in evaluate(shard, gate=gate):
                batch.append(ev)
                evaluated += 1
                if len(batch) >= result_batch:
                    t_w = time.perf_counter()
                    write_frame(sock, {"t": "results", "lease_id": lid,
                                       "payload": events_to_wire(batch)},
                                send_lock)
                    wire_s += time.perf_counter() - t_w
                    batch = []
            if batch:
                t_w = time.perf_counter()
                write_frame(sock, {"t": "results", "lease_id": lid,
                                   "payload": events_to_wire(batch)},
                            send_lock)
                wire_s += time.perf_counter() - t_w
            done = {"t": "done", "lease_id": lid, "evaluated": evaluated}
            if traced:
                now = time.perf_counter()
                done["spans"] = spans_to_wire([
                    {"name": "w-lease", "start": t_lease, "end": now,
                     "attrs": {"pid": os.getpid(),
                               "wire_ms": round(wire_s * 1e3, 3)}},
                    {"name": "w-eval", "start": t_eval, "end": now,
                     "attrs": {"evaluated": evaluated,
                               "units": len(msg["indices"])}},
                ], t_lease)
            write_frame(sock, done, send_lock)
        except OSError:
            break                         # fabric went away
        except Exception as e:            # solver bug: report, keep serving
            try:
                write_frame(sock, {"t": "error", "lease_id": lid,
                                   "error": repr(e)}, send_lock)
            except OSError:
                break
    stop.set()
    try:
        sock.close()
    except OSError:
        pass


def main() -> None:
    ap = argparse.ArgumentParser(
        description="attach solve worker process(es) to a SolveFabric")
    ap.add_argument("address", help="HOST:PORT the fabric listens on "
                                    "(launch/serve.py --fabric prints it)")
    ap.add_argument("--procs", type=int, default=1,
                    help="worker processes to run from this invocation "
                         "(each gets its own connection and lease window)")
    ap.add_argument("--hb-interval", type=float, default=HB_INTERVAL,
                    help="seconds between liveness heartbeat frames "
                         "(0 disables; the fabric then falls back to "
                         "lease timeouts for dead-worker detection)")
    args = ap.parse_args()
    if args.procs <= 1:
        run_worker(args.address, hb_interval=args.hb_interval)
        return
    import multiprocessing as mp

    procs = [mp.Process(target=run_worker, args=(args.address,),
                        kwargs={"hb_interval": args.hb_interval})
             for _ in range(args.procs)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()


if __name__ == "__main__":
    main()
