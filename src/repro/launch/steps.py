"""Step functions shared by the trainer, server, and dry-run."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import Model
from ..optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_state = adamw.update(opt_cfg, grads, opt_state, params)
        return loss, new_params, new_state

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache

    return serve_step
