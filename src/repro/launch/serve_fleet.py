"""Fleet launcher: MANY servers, ONE planning plane.

    PYTHONPATH=src python -m repro.launch.serve_fleet --smoke

Runs three ``Server`` instances with genuinely different model configs
-- a dense transformer (qwen2_7b), an MoE (olmoe_1b_7b), and an SSM
(mamba2_370m) -- against ONE shared :class:`PlanService`, one shared
plan store, and (with ``--fabric``) one shared solve fabric.  Each
server is a registered **tenant** with its own QoS class
(:mod:`repro.runtime.tenancy`):

* ``interactive`` -- drains first; its KV-pool ticket must not sit
  behind anyone's batch work.
* ``batch`` -- a band behind, quota-capped; with ``--noise N`` it also
  floods N unique cold solves first, so the pool is *saturated* before
  the interactive server ever submits (the starvation scenario QoS
  exists to prevent).
* ``best_effort`` -- last band, one shard per solve, two in flight;
  over-quota submits defer (fallback artifact still serves -- the
  server starts ticking regardless) and a full backlog sheds honestly.

Every server serves synthetic traffic concurrently, then the launcher
prints per-tenant ticket latency and the per-tenant stats slices --
which sum, counter for counter, to the global ``service.stats``.

``--tenants name:qos:arch,...`` overrides the fleet composition;
``benchmarks/run.py --only multi_tenant`` runs the same contention
story headlessly and records the QoS-on vs QoS-off p95 gap.
"""

from __future__ import annotations

import argparse
import threading
import time

# (tenant, qos class, arch id): a transformer, an MoE, and an SSM --
# three genuinely different model families on one planning plane
DEFAULT_FLEET = (
    ("interactive", "interactive", "qwen2_7b"),
    ("batch", "batch", "olmoe_1b_7b"),
    ("best_effort", "best_effort", "mamba2_370m"),
)


def _noise_program(i: int, dims: int = 4096):
    """A unique cold banking problem (per ``i``): solver saturation."""
    from ..core import AccessDecl, Counter, Ctrl, MemorySpec, Program, Sched
    from ..core.polytope import Affine
    mem = MemorySpec(f"noise{i}", dims=(dims,), word_bits=32, ports=1)
    return Program(
        root=Ctrl(
            "reader", Sched.INNER,
            counters=[Counter("i", start=0, step=1, count=32 + i, par=8)],
            accesses=[AccessDecl(mem.name, (Affine.of(i=1),), label="rd")],
        ),
        memories={mem.name: mem},
    ), mem.name


def main():
    ap = argparse.ArgumentParser(
        description="run a multi-tenant server fleet over ONE PlanService")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CPU-sized)")
    ap.add_argument("--tenants", default=None,
                    help="fleet spec name:qos:arch[,name:qos:arch...] "
                         "(default: interactive/batch/best_effort over "
                         "qwen2_7b/olmoe_1b_7b/mamba2_370m)")
    ap.add_argument("--requests", type=int, default=4,
                    help="synthetic requests per server")
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--noise", type=int, default=6,
                    help="unique cold solves the batch tenant floods "
                         "BEFORE the fleet submits (solver saturation)")
    ap.add_argument("--workers", type=int, default=2,
                    help="shared service worker-pool width")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-store", default=None,
                    help="shared DirectoryStore path (one store for the "
                         "whole fleet)")
    ap.add_argument("--fabric", action="store_true",
                    help="open a shared SolveFabric listener and print "
                         "the address to attach solve workers to")
    ap.add_argument("--fabric-wait-workers", type=int, default=0)
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="print global + per-tenant stats every N seconds")
    ap.add_argument("--trace-dir", default=None,
                    help="enable plan-plane tracing; the flight recorder "
                         "dumps Chrome trace_event JSON here on exit and "
                         "on anomalies")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics, /traces and /stats on "
                         "127.0.0.1:PORT (0 = ephemeral)")
    args = ap.parse_args()

    import json

    import numpy as np

    from ..configs import get_arch
    from ..core.fabric import SolveFabric
    from ..core.service import PlanService
    from ..core.store import DirectoryStore
    from ..models import get_model
    from ..runtime.server import Request, Server, page_ticket
    from ..runtime.tenancy import TenantRegistry

    fleet = []
    for spec in (args.tenants.split(",") if args.tenants
                 else [":".join(f) for f in DEFAULT_FLEET]):
        name, qos, arch = spec.split(":")
        fleet.append((name, qos, arch))

    # ---- the ONE shared planning plane --------------------------------
    store = DirectoryStore(args.plan_store) if args.plan_store else None
    fabric = None
    if args.fabric:
        fabric = SolveFabric()
        print(f"shared solve fabric on {fabric.address} -- attach with: "
              f"python -m repro.launch.solve_worker {fabric.address}")
        if args.fabric_wait_workers:
            fabric.wait_for_workers(args.fabric_wait_workers, timeout=30.0)
            print(f"fabric: {fabric.workers_alive} workers attached")
    registry = TenantRegistry()
    for name, qos, _ in fleet:
        registry.register(name, qos)
    service = PlanService(
        store=store, workers=args.workers,
        executor="fabric" if fabric is not None else "pool",
        fabric=fabric, tenants=registry)
    print("tenants:", ", ".join(f"{n} (qos={q}, arch={a})"
                                for n, q, a in fleet))
    obs_server = None
    if args.trace_dir is not None or args.metrics_port is not None:
        service.enable_tracing(trace_dir=args.trace_dir)
        if args.metrics_port is not None:
            from ..core.tracing import start_observability_server
            obs_server = start_observability_server(
                service.metrics, service.recorder, tracer=service.tracer,
                port=args.metrics_port)
            host_, port_ = obs_server.server_address[:2]
            print(f"metrics: http://{host_}:{port_}/metrics")

    if args.stats_interval > 0:
        def _stats_loop():
            # per-tenant slices nest under "tenants"; live fabric
            # heartbeat/lease counters ride along when a fabric is up
            while True:
                time.sleep(args.stats_interval)
                line = service.stats.as_dict()
                if fabric is not None:
                    line["fabric"] = {
                        "workers_alive": fabric.workers_alive,
                        "heartbeats": fabric.stats.heartbeats,
                        "leases": fabric.stats.leases,
                    }
                print("stats:", json.dumps(line))
        threading.Thread(target=_stats_loop, daemon=True,
                         name="fleet-stats").start()

    # ---- saturate first: the batch tenant floods unique cold solves ---
    noise_name = next((n for n, q, _ in fleet if q == "batch"),
                      fleet[-1][0])
    noise_tickets = []
    for i in range(args.noise):
        program, memory = _noise_program(i)
        noise_tickets.append(service.submit(
            program, memory, use_cache=False, tenant=noise_name))
    n_deferred = sum(1 for t in noise_tickets if t.deferred)
    if noise_tickets:
        print(f"noise: {len(noise_tickets)} unique cold solves from "
              f"{noise_name!r} ({n_deferred} deferred by admission; every "
              f"ticket's fallback artifact is still servable)")

    # ---- the fleet: one thread per server, one service under all ------
    results = {}
    errors = {}

    def run_server(name: str, arch: str, offset: int) -> None:
        try:
            cfg = get_arch(arch)
            if args.smoke:
                cfg = cfg.reduced()
            model = get_model(cfg)
            # distinct max_len per server: each tenant poses its OWN
            # banking problem (no cross-tenant dedup in this demo)
            max_len = args.max_len + 16 * offset
            t0 = time.perf_counter()
            ticket = page_ticket(cfg, max_len=max_len,
                                 page=min(16, max_len // 4),
                                 readers=args.max_batch,
                                 service=service, tenant=name)
            submit_ms = (time.perf_counter() - t0) * 1e3
            server = Server(model, max_batch=args.max_batch,
                            max_len=max_len, kv_plan=ticket)
            rng = np.random.default_rng(args.seed + offset)
            for uid in range(args.requests):
                prompt = rng.integers(
                    2, cfg.vocab - 1,
                    size=int(rng.integers(3, 8))).astype(np.int32)
                server.submit(Request(uid=uid, prompt=prompt,
                                      max_new=args.max_new))
            t1 = time.perf_counter()
            server.run(max_ticks=5000)
            ticket.wait(timeout=120)
            results[name] = {
                "arch": arch,
                "submit_ms": round(submit_ms, 2),
                "ticket_latency_s": (
                    round(ticket.resolved_at - ticket.submitted_at, 3)
                    if ticket.resolved_at is not None else None),
                "ticket_status": ticket.status,
                "deferred": ticket.deferred,
                "ticks": server.ticks,
                "serve_s": round(time.perf_counter() - t1, 2),
                "swaps": server.swaps,
            }
        except Exception as e:      # surfaced after the join below
            errors[name] = e

    threads = [threading.Thread(target=run_server, args=(n, a, i),
                                name=f"fleet-{n}")
               for i, (n, _, a) in enumerate(fleet)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for name, e in errors.items():
        raise SystemExit(f"server {name!r} failed: {e!r}")

    service.drain(timeout=120)
    for t in noise_tickets:
        t.wait(timeout=120)

    # ---- report -------------------------------------------------------
    print()
    for name, _, _ in fleet:
        print(f"{name:>12}: {json.dumps(results[name])}")
    stats = service.stats.as_dict()
    slices = stats.pop("tenants", {})
    print("\nglobal stats:", json.dumps({k: v for k, v in stats.items()
                                         if v}))
    for name, s in slices.items():
        print(f"  {name:>12}:", json.dumps({k: v for k, v in s.items()
                                            if v}))
    # the slices MUST sum to the global counters -- the acceptance
    # property serve_fleet demonstrates live
    mismatched = [k for k, v in stats.items()
                  if v != sum(s.get(k, 0) for s in slices.values())]
    print("slice reconciliation:",
          "exact" if not mismatched else f"MISMATCH on {mismatched}")
    if service.recorder is not None and args.trace_dir is not None \
            and service.recorder.traces():
        import os as os_mod
        path = service.recorder.dump(
            os_mod.path.join(args.trace_dir, "fleet_trace.json"))
        print(f"tracing: {len(service.recorder.traces())} ticket "
              f"traces -> {path}")
    if obs_server is not None:
        obs_server.shutdown()
    if fabric is not None:
        fabric.shutdown()
    service.shutdown()
    if mismatched:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
