"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real cluster each host runs this under its own process index
(``jax.distributed.initialize`` is called when the standard cluster env
vars are present); in this container it runs single-process.  ``--smoke``
uses the reduced config so any architecture trains on CPU.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 30
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--bf16-opt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # multi-host bring-up: no-op single-process, auto-configured under a
    # cluster launcher (GKE/Borg set the coordinator env vars)
    if os.environ.get("COORDINATOR_ADDRESS"):
        import jax
        jax.distributed.initialize()

    from ..configs import get_arch
    from ..data.pipeline import DataConfig
    from ..models import get_model
    from ..optim import adamw
    from ..runtime.trainer import TrainConfig, train

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)
    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, frames_dim=cfg.d_model if cfg.family == "encdec" else 0)
    train_cfg = TrainConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir or f"/tmp/repro_{cfg.name}")
    opt_cfg = adamw.AdamWConfig(lr_peak=args.lr,
                                warmup_steps=max(args.steps // 10, 1),
                                total_steps=args.steps)
    out = train(model, data_cfg, train_cfg, opt_cfg, seed=args.seed)
    losses = out["losses"]
    print(f"done: {len(losses)} steps, loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}, stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
