"""Production mesh definition.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before importing anything else).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ('data','model'); 2 pods = 512 chips with a
    leading pure-DP 'pod' axis that crosses the slow inter-pod links exactly
    once per step (gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has -- smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
