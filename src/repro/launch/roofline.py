"""Roofline analysis (deliverable g).

For every dry-run cell, derive the three roofline terms on TPU v5e:

    compute    = FLOPs_per_chip / 197e12         (bf16 peak per chip)
    memory     = HBM_bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9 (per-link ICI)

Raw ``cost_analysis`` counts each while body once (~L undercount under
scan-over-layers) and L-extrapolation proved unstable, so the three terms
come from ANALYTIC models that are exact by construction given this
framework's own sharding policy (see EXPERIMENTS.md Roofline for the full
methodology); the per-body HLO census is kept in the JSON as cross-check.
MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (serve) is the useful-work
yardstick; MODEL/executed exposes remat + attention overhead.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --out results/roofline.json
"""

import argparse
import dataclasses
import json
import os
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link (1 effective link assumed)

from ..configs import SHAPES, get_arch
from ..configs.base import ArchConfig, ShapeConfig
from ..models import get_model
from .mesh import make_production_mesh


def _force_dryrun_devices() -> None:
    """Give XLA 512 host-platform devices for the dry-run sweep.

    Only the CLI entry point (``main``) needs this; merely importing the
    module for its analytic models / constants must NOT reconfigure jax
    for every consumer -- and an XLA_FLAGS that already pins the device
    count is left alone.
    """
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=512")


# ---------------------------------------------------------------------------
# Parameter counts / analytic FLOPs
# ---------------------------------------------------------------------------


def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = {}

    def walk(t, p=""):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, f"{p}/{k}")
        else:
            flat[p] = float(np.prod(t.shape))

    walk(shapes)
    total = sum(flat.values())
    expert = sum(v for k, v in flat.items() if "/we_" in k)
    if cfg.n_experts:
        active = total - expert + expert * cfg.top_k / cfg.n_experts
    else:
        active = total
    return {"total": total, "active": active, "expert": expert}


def analytic_flops(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Executed + useful FLOPs for one step (GLOBAL, all chips)."""
    pc = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        ctx = shape.seq_len / 2
        fwd_mult, train_mult = 1.0, 3.0      # fwd + 2x bwd
        remat_mult = 4.0 / 3.0               # full remat re-forward
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        ctx = shape.seq_len / 2
        fwd_mult, train_mult, remat_mult = 1.0, 1.0, 1.0
    else:  # decode: one token against a seq_len context
        tokens = shape.global_batch * 1
        ctx = shape.seq_len
        fwd_mult, train_mult, remat_mult = 1.0, 1.0, 1.0

    matmul = 2.0 * pc["active"] * tokens
    # attention score+AV flops per token ~ 4 * ctx * H * Dh per attn layer
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        n_attn = cfg.n_layers * (2 if cfg.family == "encdec" else 1)
        eff_ctx = ctx
        if cfg.sliding_window and cfg.local_global_ratio:
            r = cfg.local_global_ratio
            eff_ctx = (r * min(ctx, cfg.sliding_window) + ctx) / (r + 1)
        attn = 4.0 * eff_ctx * cfg.n_heads * cfg.hd * tokens * n_attn
    elif cfg.family == "hybrid":
        sites = max(1, cfg.n_layers // max(1, cfg.hybrid_period))
        attn = 4.0 * ctx * cfg.n_heads * cfg.hd * tokens * sites
        # SSD chunk flops ~ 2*Q*(N+P) + state update per token per layer
        d_inner = cfg.ssm_expand * cfg.d_model
        attn += tokens * cfg.n_layers * (
            2 * cfg.ssm_chunk * d_inner + 4 * d_inner * cfg.ssm_state)
    else:  # ssm
        d_inner = cfg.ssm_expand * cfg.d_model
        attn = tokens * cfg.n_layers * (
            2 * cfg.ssm_chunk * d_inner + 4 * d_inner * cfg.ssm_state)

    executed = (matmul + attn) * train_mult * remat_mult * fwd_mult
    useful = 6.0 * pc["active"] * tokens if shape.kind == "train" \
        else 2.0 * pc["active"] * tokens
    return {"executed": executed, "model_flops": useful,
            "params_total": pc["total"], "params_active": pc["active"]}


# ---------------------------------------------------------------------------
# L-extrapolated HLO census
# ---------------------------------------------------------------------------


def _with_layers(cfg: ArchConfig, L: int) -> ArchConfig:
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=L * max(1, cfg.hybrid_period))
    return dataclasses.replace(
        cfg, n_layers=L,
        n_encoder_layers=min(cfg.n_encoder_layers, L) if cfg.n_encoder_layers
        else 0)


def extrapolated_census(arch: str, shape_name: str, mesh) -> Dict[str, float]:
    """bytes + collective bytes extrapolated over the layer scan."""
    cfg = get_arch(arch)
    import repro.launch.dryrun as dr
    out = {}
    for L in (1, 2):
        cut = _with_layers(cfg, L)
        orig = dr.get_arch
        dr.get_arch = lambda n, _c=cut: _c
        try:
            r = dr.lower_cell(arch, shape_name, mesh)
        finally:
            dr.get_arch = orig
        out[L] = r
    full_L = (cfg.n_layers // max(1, cfg.hybrid_period)
              if cfg.family == "hybrid" else cfg.n_layers)

    def extrap(a: float, b: float) -> float:
        d = b - a
        if d <= 0:
            # compiler chose different fusions at L=1 vs L=2; fall back to
            # 'everything scales with depth' (per-layer = b/2)
            return (b / 2.0) * full_L
        return max(a - d, 0.0) + d * full_L

    res = {}
    for key in ("flops", "bytes_accessed"):
        res[key] = extrap(out[1][key], out[2][key])
    coll = {}
    kinds = set(out[1]["collective_bytes"]) | set(out[2]["collective_bytes"])
    for k in kinds:
        coll[k] = extrap(out[1]["collective_bytes"].get(k, 0.0),
                         out[2]["collective_bytes"].get(k, 0.0))
    res["collective_bytes"] = coll
    return res


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


def analytic_traffic(cfg: ArchConfig, shape: ShapeConfig, chips: int,
                     n_model: int, n_data: int, fsdp: bool
                     ) -> Dict[str, float]:
    """Per-chip HBM bytes + collective bytes for one step, derived from the
    sharding policy this framework actually installs (parallel/sharding.py +
    launch/dryrun.make_policy).  Used for the memory/collective roofline
    terms; the HLO census (which counts loop bodies once) is kept in the
    JSON as a cross-check.  All sizes bf16 unless stated."""
    pc = param_counts(cfg)
    D = cfg.d_model
    F = cfg.d_ff or 1
    L = cfg.n_layers
    train = shape.kind == "train"
    prefill = shape.kind == "prefill"
    decode = shape.kind in ("decode", "long_decode")

    tokens_loc = shape.global_batch * (1 if decode else shape.seq_len) \
        / max(chips // n_model, 1)
    params_loc_model = pc["total"] * 2.0 / n_model          # bf16
    params_loc_full = pc["total"] * 2.0 / chips if fsdp else params_loc_model

    hbm = 0.0
    coll = 0.0
    # --- parameters ---------------------------------------------------------
    reads = 3.0 if train else 1.0            # fwd + remat-refwd + bwd
    hbm += params_loc_full * reads
    if fsdp:
        # FSDP: AG the layer's params from the data axis, fwd+bwd
        coll += params_loc_model * (2.0 if train else 1.0)
    if train:
        # optimizer: m, v, master fp32 read+write (ZeRO-1: /chips)
        hbm += pc["total"] * 12.0 * 2.0 / chips
        # gradient reduction over data (+pod): RS+AG ~ 2x local param bytes
        coll += params_loc_model * 2.0 / (1 if fsdp else 1)

    # --- activations ---------------------------------------------------------
    act_mult = 3.0 if train else 1.0         # fwd + remat + bwd traffic
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        n_blk = L * (2 if cfg.family == "encdec" else 1)
        # gathered block inputs (r+w) + projections + mlp tiles (sharded)
        per_layer = tokens_loc * 2.0 * (6 * D + 3 * F / n_model
                                        + 2 * cfg.n_heads * cfg.hd / n_model)
        hbm += per_layer * n_blk * act_mult
        if not decode:
            # SP: AG block inputs (x2 per layer) + RS residual (x2)
            coll += tokens_loc * D * 2.0 * 4 * n_blk * act_mult / 2
        # attention KV streaming (flash-style): K+V read once per q-block
        if not decode:
            n_qblk = max(1, shape.seq_len // 512)
            kv_bytes = (shape.seq_len * 2 * cfg.n_kv_heads * cfg.hd * 2.0
                        * shape.global_batch / chips)
            eff = 1.0
            if cfg.sliding_window and cfg.local_global_ratio:
                r = cfg.local_global_ratio
                eff = (r * min(1.0, cfg.sliding_window / shape.seq_len) + 1) / (r + 1)
            hbm += kv_bytes * n_qblk * n_blk / L * L * eff * act_mult / 3
    else:  # ssm / hybrid: channel-sharded
        d_inner = cfg.ssm_expand * D
        per_layer = tokens_loc * 2.0 * (4 * D + 4 * d_inner / n_model)
        hbm += per_layer * L * act_mult
        if not decode:
            # channel-sharded residual: AR of partial sums per layer
            coll += tokens_loc * D * 2.0 * 2 * L * act_mult / 2
        if cfg.family == "hybrid":
            sites = max(1, L // max(1, cfg.hybrid_period))
            coll += tokens_loc * D * 2.0 * 4 * sites * act_mult / 2

    # --- MoE ------------------------------------------------------------------
    if cfg.n_experts and not decode:
        # a2a-style combine: psum_scatter of (tokens_loc, D) fp32 per layer;
        # the baseline XLA lowering is far worse (see census) -- we report
        # the policy-implied cost and flag the baseline separately.
        coll += tokens_loc * D * 4.0 * L * act_mult

    # --- logits / embedding ---------------------------------------------------
    if not decode:
        hbm += tokens_loc * cfg.vocab * 4.0 / n_model        # logits chunks
        coll += tokens_loc * 4.0 * 2                         # lse all-reduce
    else:
        hbm += shape.global_batch / max(chips // n_model, 1) \
            * cfg.vocab * 4.0 / n_model

    # --- decode cache streaming ----------------------------------------------
    if decode:
        # the BASELINE reads the full cache every step (window masking does
        # not reduce HBM reads); the windowed ideal lives in _cache_bytes
        # and is used as the min-bytes yardstick.
        hbm += _cache_bytes(cfg, shape, windowed=False) / chips
        coll += tokens_loc * D * 2.0 * L * 2                 # tiny partial ARs

    return {"hbm_bytes": hbm, "coll_bytes": coll}


def _cache_bytes(cfg: ArchConfig, shape: ShapeConfig,
                 windowed: bool = True) -> float:
    """Global bytes a decode step must stream from the cache.

    windowed=True gives the information-theoretic minimum (local layers
    read only their window -- what the ring-cache optimization achieves);
    windowed=False is what the baseline full-buffer layout actually reads."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        return cfg.n_layers * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4.0
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        sites = max(1, cfg.n_layers // max(1, cfg.hybrid_period))
        return (cfg.n_layers * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4.0
                + sites * B * S * 2 * cfg.n_kv_heads * cfg.hd * 2.0)
    n_attn = cfg.n_layers * (2 if cfg.family == "encdec" else 1)
    eff_S = S
    if windowed and cfg.sliding_window and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        eff_S = (r * min(S, cfg.sliding_window) + S) / (r + 1)
    return n_attn * B * eff_S * 2 * cfg.n_kv_heads * cfg.hd * 2.0


BOTTLENECK_NOTES = {
    "compute": "raise arithmetic intensity per chip (bigger per-chip tiles, "
               "less remat re-forward) or spread model FLOPs wider",
    "memory": "cut HBM traffic: fuse/reuse (flash-style blocks), shrink KV "
              "(windowed cache, quantization), avoid re-reading weights",
    "collective": "reshape the layout: fewer gathered dims, bigger per-hop "
                  "payloads, overlap collectives with compute, or compress",
}


def analyze_cell(entry: Dict[str, Any], mesh, chips: int,
                 do_extrapolate: bool = False) -> Optional[Dict[str, Any]]:
    if "error" in entry or "skipped" in entry:
        return None
    arch, shape_name = entry["arch"], entry["shape"]
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    af = analytic_flops(cfg, shape)
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)
    pbytes = af["params_total"] * 2 / n_model
    fsdp = pbytes > 2 * 2**30

    census = None
    if do_extrapolate:
        try:
            census = extrapolated_census(arch, shape_name, mesh)
        except Exception:
            traceback.print_exc()
    hlo_flops_pc = (census or entry)["flops"]
    hlo_bytes_pc = (census["bytes_accessed"] if census
                    else entry["bytes_accessed"])
    coll = (census or entry)["collective_bytes"]
    coll_total_pc = sum(coll.values())

    traffic = analytic_traffic(cfg, shape, chips, n_model, n_data, fsdp)

    t_compute = af["executed"] / chips / PEAK_FLOPS
    t_memory = traffic["hbm_bytes"] / HBM_BW
    t_collective = traffic["coll_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mfu = (af["model_flops"] / chips / PEAK_FLOPS) / max(step_time, 1e-30)
    # decode cells are bandwidth-limited by construction: the honest
    # roofline fraction is min-bytes / achieved-bytes, where min-bytes =
    # params + *windowed* cache streamed exactly once per step.
    if shape.kind in ("decode", "long_decode"):
        min_bytes_pc = (af["params_active"] * 2
                        + _cache_bytes(cfg, shape, windowed=True)) / chips
        mfu = min(min_bytes_pc / max(traffic["hbm_bytes"], 1.0), 1.0)
    return {
        "arch": arch, "shape": shape_name,
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_collective,
        "dominant": dominant,
        "model_flops": af["model_flops"],
        "executed_flops": af["executed"],
        "analytic_hbm_bytes_per_chip": traffic["hbm_bytes"],
        "analytic_coll_bytes_per_chip": traffic["coll_bytes"],
        "hlo_flops_per_chip_loopbody": hlo_flops_pc,
        "hlo_bytes_per_chip_loopbody": hlo_bytes_pc,
        "hlo_collective_bytes_loopbody": coll,
        "useful_ratio_model_over_executed": (
            af["model_flops"] / max(af["executed"], 1.0)),
        "roofline_fraction": min(mfu, 1.0),
        "note": BOTTLENECK_NOTES[dominant],
        "peak_gib": entry["memory"]["bytes_per_device_peak"] / 2**30,
        "fsdp": fsdp,
    }


def main():
    _force_dryrun_devices()   # CLI-only; importing this module never does
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-json", default="results/dryrun_singlepod.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--extrapolate", action="store_true")
    ap.add_argument("--only", default=None, help="arch:shape filter")
    args = ap.parse_args()

    with open(args.dryrun_json) as f:
        entries = json.load(f)
    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    rows = []
    for e in entries:
        if "skipped" in e or "error" in e:
            continue
        if args.only:
            a, s = args.only.split(":")
            if not (e["arch"] == a and e["shape"] == s):
                continue
        t0 = time.time()
        try:
            r = analyze_cell(e, mesh, chips,
                             do_extrapolate=args.extrapolate)
        except Exception as ex:
            traceback.print_exc()
            r = None
        if r:
            rows.append(r)
            print(f"{r['arch']:16s} {r['shape']:12s} "
                  f"comp={r['compute_s']*1e3:9.3f}ms "
                  f"mem={r['memory_s']*1e3:9.3f}ms "
                  f"coll={r['collective_s']*1e3:9.3f}ms "
                  f"dom={r['dominant']:10s} "
                  f"frac={r['roofline_fraction']*100:5.1f}% "
                  f"({time.time()-t0:.0f}s)")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
