"""Deterministic, checkpointable data pipeline.

Production posture: every batch is a pure function of (seed, step), so

* any worker can reproduce any batch (no shared queue to lose on failure),
* resume-from-checkpoint is bitwise exact (the iterator state is one int),
* each data-parallel rank slices its shard of the global batch by rank id
  (host-sharded loading; no host ever materializes the global batch at
  scale).

Two sources: a hash-based synthetic corpus (default; zipfian-ish marginals
so losses behave like text), and an optional memory-mapped token file.
A background prefetch thread keeps ``depth`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None  # memory-mapped corpus (uint32)
    frames_dim: int = 0               # >0: also emit encoder frames (encdec)


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD47A]))


def synthetic_batch(cfg: DataConfig, step: int, rank: int = 0,
                    world: int = 1) -> Dict[str, np.ndarray]:
    """Batch `step`, slice `rank`-of-`world` along the batch dim."""
    assert cfg.global_batch % world == 0
    per = cfg.global_batch // world
    rng = _batch_rng(cfg, step)
    # zipf-ish marginal over the vocab, deterministic per step
    z = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    tokens_all = (z % (cfg.vocab - 2)).astype(np.int32) + 1
    sl = slice(rank * per, (rank + 1) * per)
    out = {"tokens": tokens_all[sl, :-1], "labels": tokens_all[sl, 1:]}
    if cfg.frames_dim:
        out["frames"] = rng.standard_normal(
            (cfg.global_batch, cfg.seq_len, cfg.frames_dim)
        )[sl].astype(np.float32) * 0.02
    return out


def file_batch(cfg: DataConfig, step: int, rank: int = 0, world: int = 1,
               _mmap_cache: dict = {}) -> Dict[str, np.ndarray]:
    toks = _mmap_cache.get(cfg.token_file)
    if toks is None:
        toks = np.memmap(cfg.token_file, dtype=np.uint32, mode="r")
        _mmap_cache[cfg.token_file] = toks
    per = cfg.global_batch // world
    rng = _batch_rng(cfg, step)
    n_windows = len(toks) - cfg.seq_len - 1
    starts = rng.integers(0, n_windows, size=cfg.global_batch)
    sl = starts[rank * per:(rank + 1) * per]
    rows = np.stack([np.asarray(toks[s:s + cfg.seq_len + 1]) for s in sl])
    rows = (rows % cfg.vocab).astype(np.int32)
    return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def get_batch(cfg: DataConfig, step: int, rank: int = 0, world: int = 1):
    if cfg.token_file:
        return file_batch(cfg, step, rank, world)
    return synthetic_batch(cfg, step, rank, world)


class PrefetchingLoader:
    """Iterator with a prefetch thread; state = the next step index."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2,
                 rank: int = 0, world: int = 1):
        self.cfg = cfg
        self.rank, self.world = rank, world
        self._next_step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._fetch_step = start_step
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = get_batch(self.cfg, self._fetch_step, self.rank, self.world)
            step = self._fetch_step
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            self._fetch_step += 1

    def __next__(self):
        step, batch = self._q.get()
        # guard against raced restarts: regenerate if out of order
        if step != self._next_step:
            batch = get_batch(self.cfg, self._next_step, self.rank, self.world)
        self._next_step += 1
        return batch

    @property
    def state(self) -> int:
        """Checkpointable iterator state: the next step to consume."""
        return self._next_step

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
