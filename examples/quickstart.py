"""Quickstart: the plan -> compile -> execute flow of the banking system.

1. **Plan**: ``BankingPlanner.plan`` poses the banking problem and returns
   a durable ``BankingPlan`` keyed by a canonical program signature
   (structurally identical programs hit the cache, never re-solve).
2. **Compile**: ``plan.compile()`` lowers the chosen scheme ONCE into a
   ``CompiledBankingPlan`` -- the executable artifact owning the physical
   layout, the jit-ready BA/BO resolution callables, pack/unpack, the
   Pallas banked-gather binding, and the PartitionSpec bridge.  Artifacts
   are cached on the planner by (plan signature, backend) and serialize
   to JSON next to the plan cache.
3. **Execute**: everything outside ``repro.core`` talks to the artifact;
   direct access to ``BankingSolution`` fields (``.geometry``,
   ``.resolution_ba``/``_bo``) from kernels/runtime/parallel code is
   deprecated and gone.

    PYTHONPATH=src python examples/quickstart.py

(The older free functions ``partition_memory`` / ``partition_all`` still
work but are deprecated shims over this planner.)
"""

import json

import jax.numpy as jnp
import numpy as np

from repro.core import (AccessDecl, BankingPlanner, CompiledBankingPlan,
                        Counter, Ctrl, MemorySpec, Program, Sched)
from repro.core.polytope import Affine
from repro.kernels import ref


def main():
    # A 1-D table read by 8 vectorized lanes each cycle (Fig. 1 flow).
    mem = MemorySpec("table", dims=(256,), word_bits=32, ports=1)
    program = Program(
        root=Ctrl(
            "reader", Sched.INNER,
            counters=[Counter("i", start=0, step=1, count=32, par=8)],
            accesses=[AccessDecl("table", (Affine.of(i=1),), label="rd")],
        ),
        memories={"table": mem},
    )

    planner = BankingPlanner()          # scorer="proxy" by default
    plan = planner.plan(program, "table")
    print(f"signature: {plan.signature}")
    print(f"groups: {[len(g) for g in plan.groups]}")
    print(f"candidates examined: {plan.num_candidates} "
          f"in {plan.solve_seconds*1e3:.1f} ms (scorer={plan.scorer_name})")
    print("top 3 schemes:")
    for s in plan.solutions[:3]:
        print("  ", s.describe())

    # Structurally identical program -> signature-keyed cache hit, no solve.
    again = planner.plan(program, "table")
    print(f"replanning the same program: status={again.status} "
          f"(stats: {planner.stats})")

    # COMPILE: lower the chosen scheme once.  The artifact owns the layout
    # and the Eq. 1-2 + Sec-3.4 resolution circuit; recompiling is a cache
    # hit on the planner, and artifacts JSON-round-trip so a warm-started
    # planner skips re-lowering too.
    art = plan.compile()
    print("compiled:", art.describe())
    art = CompiledBankingPlan.from_json(json.loads(json.dumps(art.to_json())))

    # EXECUTE: pack data bank-major per the artifact's layout and gather
    # through the Pallas kernel -- the compiled bank-resolution arithmetic
    # runs in the BlockSpec index_map.
    D = 16
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, D)),
                       jnp.float32)
    table = art.pack(flat)
    print(f"bank-major table shape: {art.layout.table_shape(D)}")
    idx = jnp.asarray([0, 7, 63, 101, 255, 128, 33, 200], jnp.int32)
    got = art.gather(table, idx)
    want = ref.banked_gather_reference(flat, idx)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(art.unpack(table)) == np.asarray(flat)).all()
    print(f"banked_gather over {art.n_banks} banks "
          f"(from the JSON-round-tripped artifact): exact ✓")
    raw = plan.best.raw_ops
    print(f"raw mul/div/mod left in resolution arithmetic: {raw} "
          f"(DSP-free: {plan.best.dsp_free})")


if __name__ == "__main__":
    main()
