"""Quickstart: the submit -> ticket -> compile -> execute flow.

1. **Submit**: ``PlanService.submit`` poses the banking problem and
   returns a ``PlanTicket`` immediately -- the solver runs on a worker
   pool, not on your thread.  Warm caches and warm plan stores
   (``store=`` / ``DirectoryStore``) answer before the ticket is even
   returned.
2. **Execute NOW**: ``ticket.fallback()`` is an always-available compiled
   artifact (trivial single-bank scheme, zero solver work) -- pack data
   and gather through the Pallas kernel while the real solve is in
   flight.
3. **Hot-swap**: once ``ticket.done()``, ``ticket.artifact()`` is the
   solved ``CompiledBankingPlan``; unpack from the fallback layout and
   repack into the solved one -- identical gather results, now with the
   conflict-free multi-bank layout.

The blocking path still exists: ``BankingPlanner.plan`` is literally
``service.submit(...).result()`` -- one code path, two front doors.

Under the hood every cold solve is **sharded**: the worker enumerates a
``CandidateSpace`` (pruned candidate descriptors, no evaluation), splits
it into self-contained ``SolveShard`` s, and fans them across the pool;
a reducer merges the streams.  The ticket exposes the merge live --
``ticket.best_so_far()`` is the best scheme found *so far* (never
regresses), so a consumer can promote to it before the search drains,
and ``ticket.result()`` still lands on the exact scheme the monolithic
search would have chosen.

The shards don't have to run in this process: a ``SolveFabric`` leases
the same work units to **remote worker processes** over a socket
(``launch/solve_worker.py``) and broadcasts best-so-far cut bounds so
they prune like local shards -- the last section below solves the same
problem on two worker subprocesses and gets the identical winner.

And the loop **closes on measurement**: ``service.enable_telemetry()``
times every banked gather/scatter through the compiled artifacts,
ranks plans with ``scorer="measured"`` (observed latency blended with
the ML prediction, roofline prior for schemes never run), refreshes
the persisted ML scorer from the accumulated measurements, and
**demotes** a stored plan the measurements prove slow -- it loses its
cache slot, a speculative re-solve runs, and a live server hot-swaps
to the winner.  ``launch/serve.py --telemetry`` arms the same loop for
real serving.

Finally, nothing is taken on faith: **lint -> solve -> certify**
(``repro.analysis``).  ``lint_program`` vets the Program before any
solve queues (out-of-bounds accesses, degenerate counters, Sym
collisions, port over-subscription); ``submit(..., verify="store")``
re-proves every solver output conflict-free through an *independent*
decision procedure before it caches and persists the machine-checkable
certificate beside the plan; ``verify="all"`` extends the same check to
every result batch a remote fabric worker streams back, so a forged
solution is rejected and the solve still converges to the exact
monolithic answer.  A refuted scheme yields a concrete
``Counterexample`` that renders as a standalone pytest case.
``launch/serve.py --verify {off,store,all}`` arms serving the same way.

And the plane is **multi-tenant** (``repro.runtime.tenancy``): register
tenants under named QoS classes (``interactive`` / ``batch`` /
``best_effort``) and every ``submit(..., tenant=...)`` lands in that
tenant's priority band, pays its quotas (over-quota cold solves are
*deferred* -- the ticket says so and its fallback still serves -- or
*shed* with a loud ``AdmissionError``), and shows up in an exactly
reconciling per-tenant stats slice (``stats.for_tenant``).  A
saturating batch tenant cannot starve the interactive band.  The last
section below runs the whole story on one service;
``launch/serve_fleet.py`` scales it to three real model servers
(transformer / MoE / SSM) on one shared planning plane.

Finally, planning is **joint**: a model serves through *several* banked
memories at once (KV pool + MoE dispatch + SSM state), and the fabric
they share has ONE budget.  ``service.submit_joint`` bundles every
memory of a Program into one ``JointTicket``: each member solve keeps a
small Pareto frontier (cost x resources) instead of a single argmin, an
exact co-selection picks one scheme per memory minimizing total cost
under the shared ``ResourceBudget``, and a trivial single-bank point on
every frontier means a selection always exists -- an infeasible budget
degrades gracefully, never raises.  With slack budget the joint answer
is *identical* to independent planning; under pressure it trades the
cheapest memory down so the whole model fits.  A server built on the
joint ticket promotes ALL its pools atomically between decode ticks
(``launch/serve.py --joint --budget-bram N``).

And the whole plane is **observable**: ``service.enable_tracing()``
gives every ticket a trace_id with hierarchical spans across
submit -> admission -> queue -> solve -> certify (remote fabric worker
spans stitch into the same trace over the wire), a bounded flight
recorder dumps Chrome-trace JSON on demand or on anomaly (latency SLO,
cert rejection, demotion), and a ``MetricsRegistry`` mirrors every
stats counter behind Prometheus-text ``/metrics`` served by a stdlib
HTTP thread (``launch/serve.py --trace-dir DIR --metrics-port P``).
The last section below enables the tracer, runs a cold solve, dumps
the Chrome trace, and scrapes ``/metrics``.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (AccessDecl, Counter, Ctrl, MemorySpec, PlanService,
                        Program, Sched)
from repro.core.polytope import Affine
from repro.kernels import ref


def main():
    # A 1-D table read by 8 vectorized lanes each cycle (Fig. 1 flow).
    mem = MemorySpec("table", dims=(256,), word_bits=32, ports=1)
    program = Program(
        root=Ctrl(
            "reader", Sched.INNER,
            counters=[Counter("i", start=0, step=1, count=32, par=8)],
            accesses=[AccessDecl("table", (Affine.of(i=1),), label="rd")],
        ),
        memories={"table": mem},
    )

    # SUBMIT: returns a ticket, not a plan -- the solve is backgrounded.
    # (Pass store="plans/" to share solved plans across processes.)
    service = PlanService(workers=2)
    ticket = service.submit(program, "table")
    print(f"submitted: signature={ticket.signature} status={ticket.status}")

    # EXECUTE NOW: the fallback artifact needs no solver -- serve from it.
    D = 16
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, D)),
                       jnp.float32)
    fb = ticket.fallback()
    print("fallback :", fb.describe())
    table = fb.pack(flat)
    idx = jnp.asarray([0, 7, 63, 101, 255, 128, 33, 200], jnp.int32)
    first = fb.gather(table, idx)
    want = ref.banked_gather_reference(flat, idx)
    assert (np.asarray(first) == np.asarray(want)).all()
    print("served from the fallback while the solver ran: exact ✓")

    # HOT-SWAP: block for the solved plan (a server would poll done()
    # between ticks), repack, and gather identically through the solved
    # resolution circuit -- the compiled BA/BO arithmetic runs in the
    # Pallas BlockSpec index_map, where an FPGA would put the circuit.
    plan = ticket.result(timeout=60)
    print(f"solved   : {plan.num_candidates} candidates in "
          f"{plan.solve_seconds*1e3:.1f} ms (scorer={plan.scorer_name})")
    art = ticket.artifact()
    print("artifact :", art.describe())
    table = art.pack(fb.unpack(table))        # logical rows survive the swap
    got = art.gather(table, idx)
    assert (np.asarray(got) == np.asarray(first)).all()
    print(f"hot-swapped to {art.n_banks} banks: identical gather ✓")

    # Batched execution: a stacked (T, R) index matrix -- e.g. one decode
    # tick's reads for every active sequence -- is ONE kernel launch.
    ticks = jnp.stack([idx[:4], idx[4:]])     # (2 row-sets, 4 rows each)
    batched = art.gather(table, ticks)
    assert batched.shape == (2, 4, D)
    print(f"batched gather over {ticks.shape} indices: one pallas_call ✓")

    # Structurally identical resubmit: answered before the ticket returns.
    again = service.submit(program, "table")
    print(f"resubmit : done={again.done()} status={again.result().status} "
          f"(service stats: {service.stats})")
    raw = plan.best.raw_ops
    print(f"raw mul/div/mod left in resolution arithmetic: {raw} "
          f"(DSP-free: {plan.best.dsp_free})")

    # The sharded search, progressively: a cold resubmit (use_cache=False)
    # fanned over 4 shards streams its best-so-far through the ticket --
    # a server would promote its layout on each improvement and still get
    # the identical final winner from result().
    live = service.submit(program, "table", use_cache=False, shard_budget=4)
    trajectory = []
    while not live.wait(0.0005):
        best = live.best_so_far()
        if best is not None and (not trajectory
                                 or best.score != trajectory[-1]):
            trajectory.append(best.score)
    final = live.result(timeout=60)
    print(f"sharded  : best-so-far scores {trajectory} -> "
          f"winner {final.best.score:.1f} "
          f"({service.stats.shards_spawned} shards, "
          f"{service.stats.best_promotions} promotions)")
    assert final.best.geometry == plan.best.geometry

    # The same space, enumerated by hand (what the service does inside):
    from repro.core import CandidateSpace, build_groups, unroll
    up = unroll(program)
    space = CandidateSpace(mem, build_groups(up, "table"), up.iterators)
    shards = space.shards(4)
    print(f"space    : {len(space)} candidates in "
          f"{len(space.sections)} sections -> "
          f"shards of {[len(s) for s in shards]}")

    # MEASURE -> REFRESH -> DEMOTE: enable telemetry and the service
    # times the artifacts it hands out, persists the observations
    # through the plan store (telemetry/ sidecar under a DirectoryStore),
    # and self-corrects rankings the hardware contradicts.
    hub = service.enable_telemetry()
    measured_plan = service.submit(program, "table",
                                   scorer="measured").result(timeout=60)
    m_art = service.planner.compile(measured_plan, backend="numpy")
    packed = np.asarray(m_art.pack(np.asarray(flat)))
    for _ in range(4):
        m_art.gather(packed, np.asarray(idx))     # each call is measured
    print(f"telemetry: {service.stats.observations} timed calls in the "
          f"log ({len(hub.log)} distinct (scheme, op, shape) records)")
    # the hardware disagrees with the ranking: a rival scheme measures
    # 100x faster, and the served scheme keeps proving slow -> the
    # service demotes it and re-solves speculatively, exactly once
    hub.log.observe(measured_plan.signature, "rival-scheme", "numpy",
                    "gather", (8,), 1e-5)
    for _ in range(hub.config.min_observations):
        hub.observe(m_art, "gather", (8,), 1e-3)
    replacement = hub.replacement((measured_plan.signature, "measured"))
    print(f"demotion : {service.stats.demotions} demoted, re-solve "
          f"ticket={replacement.status if replacement else None} "
          f"(a Server polls hub.replacement() and hot-swaps mid-serve)")
    # with enough measured schemes, hub.refresh() refits the persisted
    # ml_scorer.json from (features, measured-us) pairs -- the paper's
    # ML cost model, now trained by your own hardware.

    # LINT -> SOLVE -> CERTIFY: nothing is taken on faith.  The lint
    # pass vets the Program before any solve queues; verify="store"
    # re-proves the solver's chosen scheme conflict-free through an
    # INDEPENDENT decision procedure (lattice + residue witnesses, not
    # the solver's sumset DP) and persists the machine-checkable
    # certificate beside the plan; verify="all" extends the same check
    # to every remote fabric result batch.
    import dataclasses

    from repro.analysis import (certify_plan, certify_solution,
                                check_certificate, lint_program)
    report = lint_program(program, "table")
    print(f"lint     : ok={report.ok} "
          f"({len(report.diagnostics)} findings)")
    verified = service.submit(program, "table", use_cache=False,
                              verify="store").result(timeout=60)
    res = certify_plan(verified, up.iterators)
    ok, _why = check_certificate(res.certificate)
    print(f"certify  : {res.pairs_checked} access pairs re-decided in "
          f"{res.seconds*1e3:.1f} ms -> verdict={res.certificate.verdict} "
          f"(independent recheck: {ok})")
    # ...and a forged scheme is refuted with a concrete collision that
    # renders as a standalone pytest case (Counterexample.to_pytest):
    forged = dataclasses.replace(
        verified.best,
        geometry=dataclasses.replace(verified.best.geometry, N=1, B=1))
    refuted = certify_solution(forged, build_groups(up, "table"),
                               up.iterators)
    assert not refuted.ok and refuted.counterexample is not None
    print(f"refuted  : forged single-bank scheme -> "
          f"{refuted.counterexample.describe()}")

    # DISTRIBUTED: the identical search, but the shards run in OTHER
    # PROCESSES attached over a socket.  A SolveFabric leases work units
    # to remote workers, streams their scored solutions back into one
    # reducer, and broadcasts the reducer's cuts so remote shards prune
    # like local ones.  In production: `launch/serve.py --fabric` prints
    # the address, and `launch/solve_worker.py HOST:PORT` attaches one
    # worker per host -- here we spawn two locally.
    from repro.core import SolveFabric, spawn_local_workers
    fabric = SolveFabric()
    workers = spawn_local_workers(fabric.address, 2)
    try:
        assert fabric.wait_for_workers(2, timeout=30)
        service.attach_fabric(fabric)
        dist = service.submit(program, "table", use_cache=False,
                              executor="fabric")
        # best-so-far promotions stream exactly as in-process...
        remote_plan = dist.result(timeout=120)
        # ...and the winner is the same scheme, solved on other processes
        assert remote_plan.best.geometry == plan.best.geometry
        print(f"fabric   : same winner from {service.stats.fabric_leases} "
              f"remote leases across 2 workers "
              f"({service.stats.fabric_cut_broadcasts} cut broadcasts)")
    finally:
        for w in workers:
            w.terminate()
        for w in workers:
            w.wait()
        fabric.shutdown()

    # MULTI-TENANT: one planning plane, many tenants.  QoS classes map
    # to priority bands + weighted fair share; quotas defer over-quota
    # cold solves (the ticket says so, and its fallback artifact still
    # serves NOW) or shed them with a loud AdmissionError; stats slice
    # per tenant and reconcile exactly with the global counters.
    from repro.core import QoSClass, TenantRegistry
    tenants = TenantRegistry()
    tenants.register("web", "interactive")       # stock class: band 0
    tenants.register("nightly", QoSClass(        # custom: band 10, 1 slot
        "nightly", priority=10, max_inflight=1))
    shared = PlanService(workers=2, tenants=tenants)

    def unique(i):
        m = MemorySpec(f"t{i}", dims=(256 + 8 * i,), word_bits=32,
                       ports=1)
        return Program(
            root=Ctrl("reader", Sched.INNER,
                      counters=[Counter("i", 0, 1, 32, par=8)],
                      accesses=[AccessDecl(m.name, (Affine.of(i=1),))]),
            memories={m.name: m}), m.name

    flood = [shared.submit(*unique(i), tenant="nightly")
             for i in range(4)]                  # 1 admitted, 3 deferred
    vip = shared.submit(*unique(99), tenant="web")
    n_deferred = sum(t.deferred for t in flood)
    flood[-1].fallback(backend="numpy")          # deferred != denied
    vip.result(timeout=60)                       # band 0 lands first
    for t in flood:
        t.result(timeout=60)                     # ...but everyone lands
    g = shared.stats.as_dict()
    slices = g.pop("tenants")
    exact = all(v == sum(s.get(k, 0) for s in slices.values())
                for k, v in g.items())
    print(f"tenancy  : nightly deferred {n_deferred}/4 cold solves while "
          f"web's solved; per-tenant slices reconcile exactly: {exact}")
    shared.shutdown()

    # JOINT: one model, many banked memories, ONE fabric budget.  A
    # two-memory Program (a KV pool and an MoE dispatch table) goes
    # through submit_joint: each member keeps a Pareto frontier of
    # (cost, resources) schemes, and an exact co-selection picks one
    # scheme per memory minimizing total cost under the shared budget.
    from repro.core import ResourceBudget
    kv = MemorySpec("kv", dims=(256,), word_bits=16, ports=1)
    disp = MemorySpec("disp", dims=(128,), word_bits=32, ports=1)
    joint_prog = Program(
        root=Ctrl("model", Sched.FORKJOIN, children=[
            Ctrl("attn", Sched.INNER,
                 counters=[Counter("r", 0, 1, 32, par=8)],
                 accesses=[AccessDecl("kv", (Affine.of(r=1),))]),
            Ctrl("route", Sched.INNER,
                 counters=[Counter("e", 0, 1, 32, par=4)],
                 accesses=[AccessDecl("disp", (Affine.of(e=1),))]),
        ]),
        memories={"kv": kv, "disp": disp},
    )
    jsvc = PlanService(workers=2)
    # slack budget: the joint answer IS the independent answer
    slack = jsvc.submit_joint(joint_prog).result(timeout=120)
    free_use = slack.total_use
    print(f"joint    : slack budget -> "
          f"{[m.chosen.num_banks for m in slack.members.values()]} banks "
          f"per memory, total {free_use.as_dict()}")
    # tight budget: independent planning would NOT fit -- joint
    # co-selection trades the cheapest memory down so the model does
    tight = ResourceBudget(bram=max(2, int(free_use.bram * 0.6)))
    squeezed = jsvc.submit_joint(joint_prog, budget=tight,
                                 use_cache=False).result(timeout=120)
    assert squeezed.fits() and squeezed.feasible
    assert not tight.admits(free_use)          # independent would blow it
    print(f"joint    : bram {free_use.bram} -> cap {tight.bram}: "
          f"co-selected {squeezed.total_use.bram} "
          f"(fits={squeezed.fits()}, independent would not)")
    jsvc.shutdown()

    # OBSERVE: every submit gets a trace_id once tracing is enabled --
    # hierarchical spans cover prepare -> queue-wait -> shard-eval ->
    # reduce (and, on a fabric, the REMOTE workers' lease/eval spans
    # stitch into the same trace over the wire).  The flight recorder
    # keeps the last N completed ticket traces and dumps Chrome
    # trace_event JSON for chrome://tracing / Perfetto; the metrics
    # registry mirrors every ServiceStats counter as
    # plan_<counter>{tenant=...} plus queue/latency histograms, served
    # as Prometheus text from a stdlib HTTP thread.
    import json as json_mod
    import tempfile
    import urllib.request

    from repro.core import start_observability_server
    osvc = PlanService(workers=2)
    osvc.enable_tracing(slo_ms=5_000.0)
    om = MemorySpec("obs", dims=(384,), word_bits=32, ports=1)
    oprog = Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, 32, par=8)],
                  accesses=[AccessDecl("obs", (Affine.of(i=1),))]),
        memories={"obs": om})
    oticket = osvc.submit(oprog, "obs", use_cache=False)
    oticket.result(timeout=120)
    trace = osvc.recorder.traces()[-1]
    stages = {s.name: round(s.duration_ms, 2) for s in trace.spans}
    with tempfile.TemporaryDirectory() as tmp:
        path = osvc.recorder.dump(f"{tmp}/trace.json")
        n_events = len(json_mod.load(open(path))["traceEvents"])
    http = start_observability_server(osvc.metrics, osvc.recorder,
                                      tracer=osvc.tracer, port=0)
    host, port = http.server_address[:2]
    prom = urllib.request.urlopen(
        f"http://{host}:{port}/metrics", timeout=10).read().decode()
    print(f"observe  : ticket {oticket.trace_id} spanned {stages}; "
          f"Chrome dump had {n_events} events; /metrics served "
          f"{len(prom.splitlines())} series (queue_ms="
          f"{oticket.as_dict()['queue_ms']:.2f})")
    http.shutdown()
    osvc.shutdown()


if __name__ == "__main__":
    main()
