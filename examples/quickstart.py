"""Quickstart: partition a memory with the banking system, inspect the
chosen scheme, and run the banked-gather Pallas kernel against it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (AccessDecl, Counter, Ctrl, MemorySpec, Program,
                        Sched, partition_memory)
from repro.core.polytope import Affine
from repro.kernels import ops, ref


def main():
    # A 1-D table read by 8 vectorized lanes each cycle (Fig. 1 flow).
    mem = MemorySpec("table", dims=(256,), word_bits=32, ports=1)
    program = Program(
        root=Ctrl(
            "reader", Sched.INNER,
            counters=[Counter("i", start=0, step=1, count=32, par=8)],
            accesses=[AccessDecl("table", (Affine.of(i=1),), label="rd")],
        ),
        memories={"table": mem},
    )

    report = partition_memory(program, "table")
    print(f"groups: {[len(g) for g in report.groups]}")
    print(f"candidates examined: {report.num_candidates} "
          f"in {report.solve_seconds*1e3:.1f} ms")
    print("top 3 schemes:")
    for s in report.solutions[:3]:
        print("  ", s.describe())
    best = report.best

    # Pack data bank-major per the scheme and gather through the kernel --
    # the bank-resolution arithmetic (Eq. 1-2 + Sec 3.4 rewrites) runs in
    # the BlockSpec index_map.
    D = 16
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, D)),
                       jnp.float32)
    table = ops.pack_banked(flat, best)
    idx = jnp.asarray([0, 7, 63, 101, 255, 128, 33, 200], jnp.int32)
    got = ops.gather_banked(table, idx, best)
    want = ref.banked_gather_reference(flat, idx)
    assert (np.asarray(got) == np.asarray(want)).all()
    print(f"banked_gather over {best.num_banks} banks: exact ✓")
    raw = best.raw_ops
    print(f"raw mul/div/mod left in resolution arithmetic: {raw} "
          f"(DSP-free: {best.dsp_free})")


if __name__ == "__main__":
    main()
