"""Quickstart: plan a memory partitioning with the BankingPlanner (the
front door of the banking system), inspect the chosen scheme, round-trip
the plan through JSON, and run the banked-gather Pallas kernel against it.

    PYTHONPATH=src python examples/quickstart.py

(The older free functions ``partition_memory`` / ``partition_all`` still
work but are deprecated shims over this planner.)
"""

import json

import jax.numpy as jnp
import numpy as np

from repro.core import (AccessDecl, BankingPlan, BankingPlanner, Counter,
                        Ctrl, MemorySpec, Program, Sched)
from repro.core.polytope import Affine
from repro.kernels import ops, ref


def main():
    # A 1-D table read by 8 vectorized lanes each cycle (Fig. 1 flow).
    mem = MemorySpec("table", dims=(256,), word_bits=32, ports=1)
    program = Program(
        root=Ctrl(
            "reader", Sched.INNER,
            counters=[Counter("i", start=0, step=1, count=32, par=8)],
            accesses=[AccessDecl("table", (Affine.of(i=1),), label="rd")],
        ),
        memories={"table": mem},
    )

    planner = BankingPlanner()          # scorer="proxy" by default
    plan = planner.plan(program, "table")
    print(f"signature: {plan.signature}")
    print(f"groups: {[len(g) for g in plan.groups]}")
    print(f"candidates examined: {plan.num_candidates} "
          f"in {plan.solve_seconds*1e3:.1f} ms (scorer={plan.scorer_name})")
    print("top 3 schemes:")
    for s in plan.solutions[:3]:
        print("  ", s.describe())

    # Structurally identical program -> signature-keyed cache hit, no solve.
    again = planner.plan(program, "table")
    print(f"replanning the same program: status={again.status} "
          f"(stats: {planner.stats})")

    # Plans are durable artifacts: JSON round-trip preserves the scheme and
    # rebuilds the resolution graphs, so a loaded plan drives the kernel.
    best = BankingPlan.from_json(json.loads(json.dumps(plan.to_json()))).best

    # Pack data bank-major per the scheme and gather through the kernel --
    # the bank-resolution arithmetic (Eq. 1-2 + Sec 3.4 rewrites) runs in
    # the BlockSpec index_map.
    D = 16
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, D)),
                       jnp.float32)
    table = ops.pack_banked(flat, best)
    idx = jnp.asarray([0, 7, 63, 101, 255, 128, 33, 200], jnp.int32)
    got = ops.gather_banked(table, idx, best)
    want = ref.banked_gather_reference(flat, idx)
    assert (np.asarray(got) == np.asarray(want)).all()
    print(f"banked_gather over {best.num_banks} banks "
          f"(from the JSON-round-tripped plan): exact ✓")
    raw = best.raw_ops
    print(f"raw mul/div/mod left in resolution arithmetic: {raw} "
          f"(DSP-free: {best.dsp_free})")


if __name__ == "__main__":
    main()
