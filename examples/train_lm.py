"""End-to-end driver: train a ~100M-param dense LM with the full stack --
banking-driven sharding, fault-tolerant trainer, checkpoints, data pipeline.

    PYTHONPATH=src python examples/train_lm.py --steps 300        # ~100M
    PYTHONPATH=src python examples/train_lm.py --quick            # tiny/CI
"""

import argparse

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig
from repro.models import get_model
from repro.optim import adamw
from repro.runtime.trainer import TrainConfig, train


def lm_100m() -> ArchConfig:
    """~100M params: 12L, d=768, 12H, ff=3072, vocab 32k (GPT-2-small-ish)."""
    return ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32_000, head_dim=64,
    )


def lm_quick() -> ArchConfig:
    return ArchConfig(
        name="lm-quick", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, head_dim=32,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_quick() if args.quick else lm_100m()
    if args.quick:
        args.steps = min(args.steps, 30)
    model = get_model(cfg)
    import jax
    n_params = sum(
        int(x.size) if hasattr(x, "size") else 0
        for x in jax.tree.leaves(
            jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"arch {cfg.name}: {n_params/1e6:.1f}M params")

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    train_cfg = TrainConfig(total_steps=args.steps,
                            ckpt_every=max(args.steps // 5, 10),
                            log_every=10, ckpt_dir=args.ckpt_dir)
    opt_cfg = adamw.AdamWConfig(lr_peak=6e-4, warmup_steps=args.steps // 10,
                                total_steps=args.steps)
    out = train(model, data_cfg, train_cfg, opt_cfg)
    losses = out["losses"]
    k = max(len(losses) // 10, 1)
    print(f"loss: first-{k} avg {sum(losses[:k])/k:.4f} -> "
          f"last-{k} avg {sum(losses[-k:])/k:.4f}")
    assert sum(losses[-k:]) < sum(losses[:k]), "loss did not decrease!"
    print("training loss decreased ✓ (resume-safe checkpoints in",
          train_cfg.ckpt_dir + ")")


if __name__ == "__main__":
    main()
