"""Batched serving demo: continuous batching with KV-cache slots.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get_arch
from repro.models import get_model
from repro.runtime.server import Request, Server, page_solution


def main():
    cfg = get_arch("qwen2_7b").reduced()
    model = get_model(cfg)

    # compiled KV-pool banking artifact: the pager reads page count / page
    # size off its physical layout (pages = banks, size = bank volume)
    art = page_solution(cfg, max_len=64, page=16, readers=4)
    print("KV pool banking scheme (pages = banks):", art.describe())
    server = Server(model, max_batch=4, max_len=64, kv_plan=art)
    print(f"page pool: {server.pager.slots} slots x "
          f"{server.pager.pages_per_slot} pages x "
          f"{server.pager.page_size} tokens")

    rng = np.random.default_rng(0)
    for uid in range(6):  # more requests than slots -> continuous batching
        prompt = rng.integers(2, cfg.vocab - 1, size=rng.integers(3, 8))
        server.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                              max_new=8))
    server.run(max_ticks=200)
    print(f"served 6 requests in {server.ticks} decode ticks "
          f"(max_batch=4 slots)")
    assert not server.queue and not server.active


if __name__ == "__main__":
    main()
