"""Batched serving demo: continuous batching with KV-cache slots.

The KV-pool banking problem goes through the async service front door:
submit returns a ticket, the server's first ticks run from the ticket's
trivial fallback artifact, and the page pool hot-swaps to the solved
banking scheme between decode ticks.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get_arch
from repro.models import get_model
from repro.runtime.server import Request, Server, page_ticket


def main():
    cfg = get_arch("qwen2_7b").reduced()

    # submit the banking problem FIRST: the solver runs in the background
    # while the model is built -- nothing blocks on the ~1s cold solve
    ticket = page_ticket(cfg, max_len=64, page=16, readers=4)
    model = get_model(cfg)

    server = Server(model, max_batch=4, max_len=64, kv_plan=ticket)
    print("first-tick KV layout (pages = banks):",
          server.pager.artifact.describe())
    print(f"page pool: {server.pager.slots} slots x "
          f"{server.pager.pages_per_slot} pages x "
          f"{server.pager.page_size} tokens")

    rng = np.random.default_rng(0)
    for uid in range(6):  # more requests than slots -> continuous batching
        prompt = rng.integers(2, cfg.vocab - 1, size=rng.integers(3, 8))
        server.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                              max_new=8))
    server.run(max_ticks=200)
    if server.swaps:
        print("hot-swapped to the solved layout mid-serve:",
              server.pager.artifact.describe())
    print(f"served 6 requests in {server.ticks} decode ticks "
          f"(max_batch=4 slots, {server.swaps} layout swap(s))")
    assert not server.queue and not server.active


if __name__ == "__main__":
    main()
