"""Compare all four systems (baseline / spatial / merlin / ours) on any of
the paper's benchmark access patterns.

    PYTHONPATH=src python examples/banking_explorer.py sobel
    PYTHONPATH=src python examples/banking_explorer.py spmv --top 5
"""

import argparse

from repro.core import baselines, problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("pattern", nargs="?", default="sobel",
                    choices=problems.STENCILS + problems.APPS + ["md_grid"])
    ap.add_argument("--top", type=int, default=3)
    args = ap.parse_args()

    prog = problems.build(args.pattern)
    memname = list(prog.memories)[0]
    mem = prog.memories[memname]
    print(f"pattern={args.pattern} memory={memname} dims={mem.dims} "
          f"ports={mem.ports}\n")

    for sysname in ("baseline", "spatial", "merlin", "ours"):
        rep = baselines.SYSTEMS[sysname](prog, memname)
        b = rep.best
        r = b.resources.total
        print(f"[{sysname:9s}] LUT={r.lut:7.0f} FF={r.ff:7.0f} "
              f"BRAM={r.bram:3d} DSP={r.dsp:2d}  {b.describe().split(' |')[0]}"
              f"  ({rep.solve_seconds*1e3:.0f} ms, "
              f"{rep.num_candidates} candidates)")
        if sysname == "ours":
            print("\n  runner-up schemes:")
            for s in rep.solutions[1:args.top + 1]:
                print("   ", s.describe())


if __name__ == "__main__":
    main()
